//! Gradient-norm outlier detection (the data-auditing workload).
//!
//! Examples with persistently large gradient norms are the ones the model
//! keeps failing to fit — mislabeled, corrupted, or genuinely hard
//! (`examples/outlier_detection.rs` demonstrates the signal offline; this
//! detector runs it *online*, on the per-step norms the fused engine
//! already streams for free).
//!
//! Two flagging rules, both against *running* statistics so no second
//! pass over the data is ever needed:
//!
//! * quantile rule: `norm > Q_p(all norms so far)` via a [`P2Quantile`];
//! * z-score rule: `norm > mean + z·std` via a Welford accumulator.
//!
//! Flag counts persist per dataset index across epochs: an example flagged
//! once may be noise, an example flagged every epoch is a labeling bug.

use crate::util::stats::Welford;
use crate::util::Json;

use super::sketch::P2Quantile;

/// Thresholding knobs (the `[telemetry]` config section carries these).
#[derive(Debug, Clone)]
pub struct OutlierConfig {
    /// Flag when the norm exceeds this quantile of the running
    /// distribution, in (0,1).
    pub quantile: f64,
    /// Flag when the norm exceeds `mean + zscore * std`.
    pub zscore: f64,
    /// Steps observed before flagging starts (the sketch needs mass first).
    pub warmup_steps: usize,
}

impl Default for OutlierConfig {
    fn default() -> Self {
        OutlierConfig {
            quantile: 0.99,
            zscore: 4.0,
            warmup_steps: 10,
        }
    }
}

/// The checkpointable slice of an [`OutlierDetector`] (PEGD v3,
/// PR 8): the persistent per-example flag counts plus the step/total
/// counters the audit ranking derives from. The running threshold
/// statistics (P² sketch, Welford) deliberately re-warm after a
/// resume — they converge within `warmup_steps`, while a reset flag
/// history would silently skew a `pegrad audit` ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct FlagState {
    /// Per-example flag count, indexed by dataset row.
    pub counts: Vec<u32>,
    /// Steps the detector has observed.
    pub steps: u64,
    /// Total flags raised across all steps.
    pub total_flags: u64,
}

/// Streaming detector with persistent per-example flag counts.
pub struct OutlierDetector {
    cfg: OutlierConfig,
    sketch: P2Quantile,
    stats: Welford,
    /// Flag count per dataset index — survives across epochs.
    flag_counts: Vec<u32>,
    steps: usize,
    total_flags: u64,
    /// Indices flagged on the most recent step (deduplicated).
    last_flagged: Vec<usize>,
}

impl OutlierDetector {
    /// Detector for a dataset of `dataset_n` examples.
    pub fn new(dataset_n: usize, cfg: OutlierConfig) -> OutlierDetector {
        assert!(cfg.quantile > 0.0 && cfg.quantile < 1.0);
        assert!(cfg.zscore > 0.0);
        OutlierDetector {
            sketch: P2Quantile::new(cfg.quantile),
            cfg,
            stats: Welford::new(),
            flag_counts: vec![0; dataset_n],
            steps: 0,
            total_flags: 0,
            last_flagged: Vec::new(),
        }
    }

    /// Current quantile threshold (`None` during warmup).
    pub fn threshold_quantile(&self) -> Option<f64> {
        (self.steps >= self.cfg.warmup_steps)
            .then(|| self.sketch.estimate())
            .flatten()
    }

    /// Current z-score threshold (`None` during warmup).
    pub fn threshold_zscore(&self) -> Option<f64> {
        (self.steps >= self.cfg.warmup_steps && self.stats.count() >= 2)
            .then(|| self.stats.mean() + self.cfg.zscore * self.stats.std())
    }

    /// Observe one step's batch: `norms[i]` is the gradient L2 norm of
    /// dataset example `indices[i]`. Flags are assigned against the
    /// thresholds from *previous* observations (so a step's own outliers
    /// cannot mask themselves), then the statistics absorb the new norms.
    ///
    /// Flags are DEDUPLICATED per step: samplers draw with replacement
    /// (and the importance sampler deliberately oversamples high-norm
    /// examples), so counting per occurrence would inflate the persistent
    /// audit counts by sampling frequency, not outlier persistence. An
    /// example's count rises by at most 1 per step. (Counts still scale
    /// with how often an example is *seen* across steps — compare flagged
    /// examples against their sampling rate when auditing IS runs.)
    ///
    /// Returns the number of distinct examples flagged this step.
    pub fn observe(&mut self, indices: &[usize], norms: &[f32]) -> usize {
        assert_eq!(indices.len(), norms.len());
        let tq = self.threshold_quantile();
        let tz = self.threshold_zscore();
        self.last_flagged.clear();
        for (&idx, &nm) in indices.iter().zip(norms) {
            if !nm.is_finite() {
                continue;
            }
            let n = nm as f64;
            let hit = tq.map(|t| n > t).unwrap_or(false)
                || tz.map(|t| n > t).unwrap_or(false);
            // only indices inside the audit table count as flags — an
            // out-of-range index (eval batch, stale config) must not make
            // total_flags disagree with the per-example counts
            if hit && !self.last_flagged.contains(&idx) {
                if let Some(c) = self.flag_counts.get_mut(idx) {
                    *c += 1;
                    self.last_flagged.push(idx);
                    self.total_flags += 1;
                }
            }
            self.sketch.push(nm);
            self.stats.push(n);
        }
        self.steps += 1;
        self.last_flagged.len()
    }

    /// Steps observed so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Total flags raised across all steps.
    pub fn total_flags(&self) -> u64 {
        self.total_flags
    }

    /// Flag count for one dataset index (0 if out of range).
    pub fn flag_count(&self, idx: usize) -> u32 {
        self.flag_counts.get(idx).copied().unwrap_or(0)
    }

    /// Indices flagged on the most recent step (deduplicated).
    pub fn last_flagged(&self) -> &[usize] {
        &self.last_flagged
    }

    /// Snapshot the persistent audit state for a checkpoint
    /// ([`FlagState`], PEGD v3).
    pub fn flag_state(&self) -> FlagState {
        FlagState {
            counts: self.flag_counts.clone(),
            steps: self.steps as u64,
            total_flags: self.total_flags,
        }
    }

    /// Restore a checkpointed [`FlagState`]. Counts are copied up to the
    /// current table size (a resized dataset keeps the overlapping
    /// prefix); threshold statistics are NOT restored — they re-warm.
    pub fn restore_flags(&mut self, st: &FlagState) {
        let n = self.flag_counts.len().min(st.counts.len());
        self.flag_counts[..n].copy_from_slice(&st.counts[..n]);
        for c in self.flag_counts[n..].iter_mut() {
            *c = 0;
        }
        self.steps = st.steps as usize;
        self.total_flags = st.total_flags;
        self.last_flagged.clear();
    }

    /// The `k` most-flagged example indices, `(index, count)`, count
    /// descending (ties broken by index for determinism).
    pub fn top_flagged(&self, k: usize) -> Vec<(usize, u32)> {
        let mut v: Vec<(usize, u32)> = self
            .flag_counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// Report object: config, counters, and the `top_k` most-flagged rows.
    pub fn to_json(&self, top_k: usize) -> Json {
        let top = self.top_flagged(top_k);
        Json::obj(vec![
            ("quantile", Json::num(self.cfg.quantile)),
            ("zscore", Json::num(self.cfg.zscore)),
            ("warmup_steps", Json::num(self.cfg.warmup_steps as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("total_flags", Json::num(self.total_flags as f64)),
            (
                "threshold_quantile",
                self.threshold_quantile().map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "threshold_zscore",
                self.threshold_zscore().map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "last_flagged",
                Json::arr_usize(&self.last_flagged),
            ),
            (
                "flagged_examples",
                Json::Arr(
                    top.iter()
                        .map(|&(i, c)| {
                            Json::obj(vec![
                                ("index", Json::num(i as f64)),
                                ("flags", Json::num(c as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_outlier_accumulates_flags() {
        let mut det = OutlierDetector::new(
            64,
            OutlierConfig {
                quantile: 0.95,
                zscore: 3.0,
                warmup_steps: 5,
            },
        );
        // 40 "epochs" of a 32-example batch: clean norms jitter in
        // [1.0, 1.5), example 31 is always 50x out in the tail
        for step in 0..40usize {
            let indices: Vec<usize> = (0..32).collect();
            let mut norms: Vec<f32> = (0..32)
                .map(|i| 1.0 + ((step * 31 + i * 17) % 97) as f32 / 97.0 * 0.5)
                .collect();
            norms[31] = 50.0;
            let flagged = det.observe(&indices, &norms);
            if step < 5 {
                assert_eq!(flagged, 0, "no flags during warmup");
            }
        }
        // z-rule alone catches the planted outlier every post-warmup step
        assert!(det.flag_count(31) >= 30, "planted outlier: {}", det.flag_count(31));
        for i in 0..31 {
            assert!(
                det.flag_count(i) <= 10,
                "clean example {i} over-flagged: {}",
                det.flag_count(i)
            );
        }
        let top = det.top_flagged(3);
        assert_eq!(top[0].0, 31);
        assert!(det.last_flagged().contains(&31));
        assert!(det.total_flags() >= 30);
    }

    #[test]
    fn replacement_duplicates_flag_once_per_step() {
        let mut det = OutlierDetector::new(
            8,
            OutlierConfig {
                warmup_steps: 0,
                ..Default::default()
            },
        );
        for _ in 0..10 {
            det.observe(&[0, 1, 2, 3], &[1.0, 1.0, 1.0, 1.0]);
        }
        // example 5 drawn twice in one batch (sampling with replacement)
        let flagged = det.observe(&[5, 5], &[100.0, 100.0]);
        assert_eq!(flagged, 1, "distinct examples, not occurrences");
        assert_eq!(det.flag_count(5), 1);
        assert_eq!(det.total_flags(), 1);
        assert_eq!(det.last_flagged(), &[5]);
    }

    #[test]
    fn warmup_suppresses_thresholds() {
        let det = OutlierDetector::new(4, OutlierConfig::default());
        assert!(det.threshold_quantile().is_none());
        assert!(det.threshold_zscore().is_none());
    }

    #[test]
    fn nan_norms_skipped() {
        let mut det = OutlierDetector::new(
            4,
            OutlierConfig {
                warmup_steps: 0,
                ..Default::default()
            },
        );
        for _ in 0..20 {
            det.observe(&[0, 1], &[1.0, f32::NAN]);
        }
        assert_eq!(det.flag_count(1), 0);
        assert!(det.threshold_zscore().unwrap().is_finite());
    }

    #[test]
    fn out_of_range_index_ignored() {
        let mut det = OutlierDetector::new(
            2,
            OutlierConfig {
                warmup_steps: 0,
                ..Default::default()
            },
        );
        for _ in 0..10 {
            det.observe(&[0], &[1.0]);
        }
        // index beyond dataset_n must not panic (eval batches etc.) and
        // must stay consistent: no count, no total, no last_flagged entry
        let flagged = det.observe(&[99], &[100.0]);
        assert_eq!(flagged, 0);
        assert_eq!(det.flag_count(99), 0);
        assert_eq!(det.total_flags(), 0);
        assert!(det.last_flagged().is_empty());
    }

    #[test]
    fn flag_state_roundtrips_and_truncates() {
        let mut det = OutlierDetector::new(
            8,
            OutlierConfig {
                warmup_steps: 0,
                ..Default::default()
            },
        );
        for _ in 0..10 {
            det.observe(&[0, 1, 2], &[1.0, 1.0, 1.0]);
        }
        det.observe(&[5], &[1000.0]);
        let st = det.flag_state();
        assert_eq!(st.counts[5], 1);
        assert_eq!(st.steps, 11);
        assert_eq!(st.total_flags, 1);
        // restore into a same-size detector: identical ranking state
        let mut fresh = OutlierDetector::new(8, OutlierConfig::default());
        fresh.restore_flags(&st);
        assert_eq!(fresh.flag_state(), st);
        assert_eq!(fresh.top_flagged(2), det.top_flagged(2));
        // thresholds re-warm: the restored sketch has no mass yet
        assert!(fresh.threshold_zscore().is_none());
        // restore into a smaller table keeps the overlapping prefix
        let mut small = OutlierDetector::new(4, OutlierConfig::default());
        small.restore_flags(&st);
        assert_eq!(small.flag_count(5), 0);
        assert_eq!(small.flag_state().steps, 11);
    }

    #[test]
    fn json_shape() {
        let mut det = OutlierDetector::new(
            8,
            OutlierConfig {
                warmup_steps: 1,
                ..Default::default()
            },
        );
        // identical clean norms: thresholds settle exactly at 1.0 and the
        // strict `>` comparison keeps the clean stream unflagged
        for _ in 0..10 {
            det.observe(&[0, 1, 2], &[1.0, 1.0, 1.0]);
        }
        det.observe(&[3], &[1000.0]);
        let j = det.to_json(16);
        assert_eq!(j.get("steps").unwrap().as_usize().unwrap(), 11);
        assert!(j.get("threshold_quantile").unwrap().as_f64().is_some());
        let flagged = j.get("flagged_examples").unwrap().as_arr().unwrap();
        assert_eq!(flagged[0].get("index").unwrap().as_usize().unwrap(), 3);
    }
}
