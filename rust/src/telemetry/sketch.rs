//! Allocation-free online accumulators for gradient-norm streams.
//!
//! * [`StreamingHistogram`] — fixed log-spaced bins (norms span decades,
//!   so linear bins would waste resolution); O(1) push, O(bins) quantile.
//! * [`P2Quantile`] — the P² algorithm (Jain & Chlamtac 1985): a single
//!   quantile tracked with five markers, O(1) push, O(1) state. No
//!   buffering, no sorting — the sketch the per-step outlier threshold
//!   reads on the hot path.
//!
//! Mean/variance accumulation reuses [`crate::util::stats::Welford`].

use crate::util::Json;

/// Streaming histogram over `(0, ∞)` with `bins` log2-spaced buckets
/// between `2^lo_log2` and `2^hi_log2`; values outside land in dedicated
/// underflow/overflow buckets (zero and negative values underflow).
#[derive(Debug, Clone)]
pub struct StreamingHistogram {
    lo_log2: f64,
    hi_log2: f64,
    counts: Vec<u64>,
    underflow: u64,
    overflow: u64,
    total: u64,
}

impl StreamingHistogram {
    /// Default range covers norms from 2^-20 (~1e-6) to 2^20 (~1e6).
    pub fn new(bins: usize) -> StreamingHistogram {
        StreamingHistogram::with_range(bins, -20.0, 20.0)
    }

    /// Histogram with an explicit log2 bucket range.
    pub fn with_range(bins: usize, lo_log2: f64, hi_log2: f64) -> StreamingHistogram {
        assert!(bins >= 2, "histogram needs >= 2 bins");
        assert!(lo_log2 < hi_log2, "empty histogram range");
        StreamingHistogram {
            lo_log2,
            hi_log2,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
            total: 0,
        }
    }

    /// Number of in-range buckets.
    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    /// In-range bucket counts.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Observations below the bucket range.
    pub fn underflow(&self) -> u64 {
        self.underflow
    }

    /// Observations above the bucket range.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Total observations, including under/overflow.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Bucket index for `x`, `None` for under/overflow.
    pub fn bin_index(&self, x: f32) -> Option<usize> {
        if !x.is_finite() || x <= 0.0 {
            return None; // underflow (zeros, negatives, NaN, ±inf)
        }
        let l = (x as f64).log2();
        if l < self.lo_log2 {
            return None;
        }
        if l >= self.hi_log2 {
            return None;
        }
        let frac = (l - self.lo_log2) / (self.hi_log2 - self.lo_log2);
        Some(((frac * self.counts.len() as f64) as usize).min(self.counts.len() - 1))
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f32) {
        self.total += 1;
        match self.bin_index(x) {
            Some(i) => self.counts[i] += 1,
            None => {
                if x.is_finite() && (x as f64).log2() >= self.hi_log2 {
                    self.overflow += 1;
                } else if !x.is_finite() && x > 0.0 {
                    self.overflow += 1; // +inf
                } else {
                    self.underflow += 1;
                }
            }
        }
    }

    /// The `bins + 1` bucket edges (geometric).
    pub fn edges(&self) -> Vec<f64> {
        let b = self.counts.len() as f64;
        (0..=self.counts.len())
            .map(|i| {
                let l = self.lo_log2 + (i as f64 / b) * (self.hi_log2 - self.lo_log2);
                l.exp2()
            })
            .collect()
    }

    /// Quantile estimate by linear interpolation in log space within the
    /// covering bucket. Underflow mass sits at the low edge, overflow at
    /// the high edge. `None` before any observation.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.total == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let target = q * self.total as f64;
        let mut cum = self.underflow as f64;
        // the low-edge shortcut is only correct when underflow mass
        // actually exists: at q = 0 (target 0.0) the comparison holds
        // vacuously at cum == 0.0 and used to report the range's low edge
        // (~1e-6) no matter where the data sat — fall through to the scan
        // instead, which lands on the first occupied bucket.
        if self.underflow > 0 && target <= cum {
            return Some(self.lo_log2.exp2());
        }
        let width = (self.hi_log2 - self.lo_log2) / self.counts.len() as f64;
        for (i, &c) in self.counts.iter().enumerate() {
            let next = cum + c as f64;
            if target <= next && c > 0 {
                let frac = (target - cum) / c as f64;
                let l = self.lo_log2 + (i as f64 + frac) * width;
                return Some(l.exp2());
            }
            cum = next;
        }
        Some(self.hi_log2.exp2())
    }

    /// Merge another histogram's counts (must share the binning).
    pub fn merge(&mut self, other: &StreamingHistogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "bin count mismatch");
        assert_eq!(
            (self.lo_log2, self.hi_log2),
            (other.lo_log2, other.hi_log2),
            "range mismatch"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.overflow += other.overflow;
        self.total += other.total;
    }

    /// Histogram as a JSON object (range, counts, over/underflow).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lo_log2", Json::num(self.lo_log2)),
            ("hi_log2", Json::num(self.hi_log2)),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("underflow", Json::num(self.underflow as f64)),
            ("overflow", Json::num(self.overflow as f64)),
            ("total", Json::num(self.total as f64)),
        ])
    }
}

/// P² single-quantile sketch (Jain & Chlamtac 1985): five markers whose
/// heights approximate `(0, p/2, p, (1+p)/2, 1)` quantiles, adjusted with
/// a piecewise-parabolic update. O(1) memory, O(1) per observation.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    p: f64,
    /// Marker heights (sorted invariant).
    q: [f64; 5],
    /// Actual marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increments per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2Quantile {
    /// Sketch tracking the `p` quantile, `p` in (0, 1).
    pub fn new(p: f64) -> P2Quantile {
        assert!(p > 0.0 && p < 1.0, "quantile must be in (0,1)");
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    /// The tracked quantile.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Observations seen.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Fold in one observation (P² marker update).
    pub fn push(&mut self, x: f32) {
        let x = x as f64;
        if !x.is_finite() {
            return; // a NaN/inf marker height would poison every estimate
        }
        self.count += 1;
        if self.count <= 5 {
            // insertion-sort the first five observations into the markers
            let k = self.count as usize;
            self.q[k - 1] = x;
            let mut i = k - 1;
            while i > 0 && self.q[i - 1] > self.q[i] {
                self.q.swap(i - 1, i);
                i -= 1;
            }
            return;
        }

        // locate the cell and clamp extremes
        let k: usize = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            // q[k] <= x < q[k+1]
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }

        // adjust interior markers
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let ds = d.signum();
                let qp = self.parabolic(i, ds);
                if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    self.q[i] = qp;
                } else {
                    self.q[i] = self.linear(i, ds);
                }
                self.n[i] += ds;
            }
        }
    }

    fn parabolic(&self, i: usize, ds: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + ds / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + ds) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - ds) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, ds: f64) -> f64 {
        let j = if ds > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + ds * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Serializable snapshot for run checkpoints. `dn` is omitted: it is
    /// a pure function of `p` and is recomputed by
    /// [`P2Quantile::from_state`].
    pub fn state(&self) -> P2State {
        P2State {
            p: self.p,
            q: self.q,
            n: self.n,
            np: self.np,
            count: self.count,
        }
    }

    /// Rebuild a sketch from a checkpointed [`P2State`]. The resumed
    /// sketch is field-for-field identical to the original — every
    /// subsequent `push` and `estimate` is bitwise the same as if the
    /// run had never stopped.
    pub fn from_state(s: &P2State) -> P2Quantile {
        assert!(s.p > 0.0 && s.p < 1.0, "checkpointed quantile out of (0,1)");
        let mut sk = P2Quantile::new(s.p);
        sk.q = s.q;
        sk.n = s.n;
        sk.np = s.np;
        sk.count = s.count;
        sk
    }

    /// Current estimate of the `p`-quantile. `None` before any
    /// observation; exact for the first five.
    pub fn estimate(&self) -> Option<f64> {
        match self.count {
            0 => None,
            c if c <= 5 => {
                // exact small-sample quantile over the sorted markers
                let k = c as usize;
                let rank = self.p * (k - 1) as f64;
                let lo = rank.floor() as usize;
                let hi = rank.ceil() as usize;
                let frac = rank - lo as f64;
                Some(self.q[lo] * (1.0 - frac) + self.q[hi] * frac)
            }
            _ => Some(self.q[2]),
        }
    }
}

/// Checkpointable [`P2Quantile`] state: the five marker heights, actual
/// and desired positions, and the observation count. The `dn` increments
/// are derivable from `p` and deliberately not part of the state.
#[derive(Debug, Clone, PartialEq)]
pub struct P2State {
    /// The tracked quantile.
    pub p: f64,
    /// Marker heights.
    pub q: [f64; 5],
    /// Actual marker positions.
    pub n: [f64; 5],
    /// Desired marker positions.
    pub np: [f64; 5],
    /// Observations seen.
    pub count: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::stats::percentile_sorted;

    #[test]
    fn histogram_counts_and_edges() {
        let mut h = StreamingHistogram::with_range(4, 0.0, 4.0); // [1,16)
        for &x in &[0.5, 1.0, 1.5, 2.0, 3.9, 4.0, 15.9, 16.0, 100.0, 0.0, -1.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 11);
        assert_eq!(h.underflow(), 3); // 0.5, 0.0, -1.0
        assert_eq!(h.overflow(), 2); // 16.0, 100.0
        assert_eq!(h.counts(), &[2, 2, 1, 1]); // [1,2):{1,1.5} [2,4):{2,3.9} [4,8):{4} [8,16):{15.9}
        let e = h.edges();
        assert_eq!(e.len(), 5);
        assert!((e[0] - 1.0).abs() < 1e-12 && (e[4] - 16.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_nan_and_inf_do_not_poison() {
        let mut h = StreamingHistogram::new(8);
        h.push(f32::NAN);
        h.push(f32::INFINITY);
        h.push(f32::NEG_INFINITY);
        h.push(1.0);
        assert_eq!(h.total(), 4);
        assert_eq!(h.underflow() + h.overflow(), 3);
    }

    #[test]
    fn histogram_quantile_brackets_exact() {
        prop::check(20, |g| {
            let mut h = StreamingHistogram::new(64);
            let n = g.usize_in(50..400);
            let mut xs: Vec<f64> = Vec::with_capacity(n);
            for _ in 0..n {
                let x = g.f32_in(0.001..100.0);
                h.push(x);
                xs.push(x as f64);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            // edge quantiles included: q = 0 and near-0 must track the
            // data minimum (not the range's low edge — the torn shortcut
            // this test regressed on), q = 1 the maximum.
            for q in [0.0, 1e-6, 0.1, 0.5, 0.9, 1.0] {
                let est = h.quantile(q).unwrap();
                // estimate must fall within one bucket of the exact value
                let exact = percentile_sorted(&xs, q * 100.0);
                let ratio = est / exact;
                let bucket = (40.0f64 / 64.0).exp2(); // one-bucket growth factor
                prop::require(
                    ratio < bucket * bucket && ratio > 1.0 / (bucket * bucket),
                    format!("q={q}: est {est} vs exact {exact}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = StreamingHistogram::new(8);
        let mut b = StreamingHistogram::new(8);
        a.push(1.0);
        b.push(2.0);
        b.push(1e30); // overflow at hi 2^20
        a.merge(&b);
        assert_eq!(a.total(), 3);
        assert_eq!(a.overflow(), 1);
    }

    #[test]
    fn p2_exact_for_first_five() {
        let mut s = P2Quantile::new(0.5);
        assert!(s.estimate().is_none());
        for x in [5.0, 1.0, 3.0] {
            s.push(x);
        }
        assert!((s.estimate().unwrap() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn p2_tracks_known_quantiles() {
        prop::check(15, |g| {
            let p = *g.choose(&[0.5, 0.9, 0.99]);
            let n = g.usize_in(500..3000);
            let mut s = P2Quantile::new(p);
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                // mix of scales, like gradient norms
                let x = g.normal().abs() * 10f32.powi(g.i64_in(-1..2) as i32);
                s.push(x);
                xs.push(x as f64);
            }
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let est = s.estimate().unwrap();
            // rank-tolerance check: the estimate must sit between the exact
            // (p-eps) and (p+eps) quantiles
            let eps = 0.06;
            let lo = percentile_sorted(&xs, ((p - eps).max(0.0)) * 100.0);
            let hi = percentile_sorted(&xs, ((p + eps).min(1.0)) * 100.0);
            prop::require(
                est >= lo && est <= hi,
                format!("p={p} n={n}: estimate {est} outside [{lo}, {hi}]"),
            )
        });
    }

    #[test]
    fn p2_state_roundtrip_is_bitwise() {
        prop::check(15, |g| {
            let p = *g.choose(&[0.5, 0.9, 0.99]);
            let mut live = P2Quantile::new(p);
            // stop both before AND after the 5-observation bootstrap
            let warm = g.usize_in(0..40);
            for _ in 0..warm {
                live.push(g.normal().abs());
            }
            let mut resumed = P2Quantile::from_state(&live.state());
            prop::require(
                live.state() == resumed.state(),
                "restored state differs".to_string(),
            )?;
            for _ in 0..g.usize_in(1..200) {
                let x = g.normal().abs();
                live.push(x);
                resumed.push(x);
            }
            let (a, b) = (live.estimate(), resumed.estimate());
            prop::require(
                match (a, b) {
                    (None, None) => true,
                    (Some(x), Some(y)) => x.to_bits() == y.to_bits(),
                    _ => false,
                },
                format!("resumed sketch diverged: {a:?} vs {b:?}"),
            )
        });
    }

    #[test]
    fn p2_ignores_non_finite() {
        let mut s = P2Quantile::new(0.9);
        for i in 0..100 {
            s.push(i as f32);
            s.push(f32::NAN);
        }
        let e = s.estimate().unwrap();
        assert!(e.is_finite() && e > 50.0 && e < 100.0, "{e}");
    }
}
