//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core + Box-Muller
//! normals (the `rand` crate is not in the vendored registry).
//!
//! Everything stochastic in the framework — init, data synthesis, sampling,
//! DP noise on the rust side — flows through this type, so runs are exactly
//! reproducible from the config seed and the RNG state can be checkpointed.

/// xoshiro256++ with SplitMix64 seeding.
#[derive(Debug, Clone, PartialEq)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal.
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// RNG seeded deterministically from `seed` (splitmix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-thread / per-component RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) with 24-bit resolution.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Standard normal via Box-Muller (caches the second draw).
    pub fn next_normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some((r * theta.sin()) as f32);
            return (r * theta.cos()) as f32;
        }
    }

    /// Serialize state for checkpointing (spare normal is dropped — costs
    /// at most one extra draw on resume, never correctness).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an RNG from a captured [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s, spare: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.1, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let skew = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02);
        assert!((var - 1.0).abs() < 0.03);
        assert!(skew.abs() < 0.05);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(11);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn state_roundtrip() {
        let mut r = Rng::new(77);
        r.next_u64();
        let saved = r.state();
        let mut r2 = Rng::from_state(saved);
        assert_eq!(r.next_u64(), r2.next_u64());
    }
}
