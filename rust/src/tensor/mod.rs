//! Dense f32 tensor substrate (the `rand`/`ndarray` crates are not in the
//! vendored registry — DESIGN.md §6).
//!
//! This backs the pure-rust reference implementation of the paper
//! ([`crate::nn`], [`crate::pegrad`]), the synthetic data generators, and
//! the E1 instrumented-flop baseline. The PJRT artifacts remain the
//! production compute path; this module is the *oracle* and the CPU
//! baseline the benches compare against.
//!
//! (System map: `docs/architecture.md`.)

pub mod conv;
pub mod kernels;
pub mod ops;
pub mod rng;
pub mod shape;
pub mod simd;

pub use rng::Rng;
pub use shape::Shape;

/// A dense, row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    // ------------------------------------------------------------ construct
    /// Build a tensor from a shape and its row-major data (panics on
    /// a length mismatch).
    pub fn new(shape: impl Into<Shape>, data: Vec<f32>) -> Self {
        let shape = shape.into();
        assert_eq!(
            shape.numel(),
            data.len(),
            "shape {:?} needs {} elements, got {}",
            shape.dims(),
            shape.numel(),
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: impl Into<Shape>) -> Self {
        let shape = shape.into();
        let n = shape.numel();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// All-ones tensor.
    pub fn ones(shape: impl Into<Shape>) -> Self {
        Self::full(shape, 1.0)
    }

    /// Tensor filled with `v`.
    pub fn full(shape: impl Into<Shape>, v: f32) -> Self {
        let shape = shape.into();
        let n = shape.numel();
        Tensor {
            shape,
            data: vec![v; n],
        }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(v: f32) -> Self {
        Tensor::new(vec![], vec![v])
    }

    /// Standard-normal tensor (Box-Muller via [`Rng`]).
    pub fn randn(shape: impl Into<Shape>, rng: &mut Rng) -> Self {
        let shape = shape.into();
        let n = shape.numel();
        Tensor {
            shape,
            data: (0..n).map(|_| rng.next_normal()).collect(),
        }
    }

    /// Uniform in [lo, hi).
    pub fn rand(shape: impl Into<Shape>, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let shape = shape.into();
        let n = shape.numel();
        Tensor {
            shape,
            data: (0..n).map(|_| lo + rng.next_f32() * (hi - lo)).collect(),
        }
    }

    // --------------------------------------------------------------- access
    /// The tensor shape.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Dimension sizes, outermost first.
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    /// Row-major element slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its row-major data.
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D element access (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.dims()[1];
        self.data[i * cols + j]
    }

    /// 2-D element store (row-major).
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.rank(), 2);
        let cols = self.dims()[1];
        self.data[i * cols + j] = v;
    }

    /// Row slice of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let cols = self.dims()[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Reshape (must preserve numel).
    pub fn reshape(mut self, shape: impl Into<Shape>) -> Self {
        let shape = shape.into();
        assert_eq!(shape.numel(), self.data.len());
        self.shape = shape;
        self
    }

    /// Scalar extraction for rank-0/1-element tensors.
    pub fn item(&self) -> f32 {
        assert_eq!(self.numel(), 1, "item() on tensor with {} elems", self.numel());
        self.data[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.at2(1, 2), 5.0);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "elements")]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn fills() {
        assert!(Tensor::zeros(vec![3, 3]).data().iter().all(|&x| x == 0.0));
        assert!(Tensor::ones(vec![4]).data().iter().all(|&x| x == 1.0));
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    fn randn_moments() {
        let mut rng = Rng::new(7);
        let t = Tensor::randn(vec![20_000], &mut rng);
        let mean: f32 = t.data().iter().sum::<f32>() / 20_000.0;
        let var: f32 = t.data().iter().map(|x| x * x).sum::<f32>() / 20_000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.data(), t.data());
        assert_eq!(r.dims(), &[3, 2]);
    }

    #[test]
    fn set2_roundtrip() {
        let mut t = Tensor::zeros(vec![2, 2]);
        t.set2(0, 1, 9.0);
        assert_eq!(t.at2(0, 1), 9.0);
    }
}
