//! Tensor shapes (row-major, static rank ≤ 4 in practice).

/// Dimension list; rank 0 = scalar.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(Vec<usize>);

impl Shape {
    /// Shape from a dimension list.
    pub fn new(dims: Vec<usize>) -> Self {
        Shape(dims)
    }

    /// The dimension list.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count (1 for rank 0).
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Row-major strides (in elements).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.0[i + 1];
        }
        s
    }

    /// Matrix rows/cols helpers for the rank-2 fast paths.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2, "rows() on rank-{}", self.rank());
        self.0[0]
    }

    /// Columns of a rank-2 shape (panics otherwise).
    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2, "cols() on rank-{}", self.rank());
        self.0[1]
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

impl<const N: usize> From<[usize; N]> for Shape {
    fn from(v: [usize; N]) -> Self {
        Shape(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_rank() {
        assert_eq!(Shape::from(vec![2, 3, 4]).numel(), 24);
        assert_eq!(Shape::from(vec![]).numel(), 1); // scalar
        assert_eq!(Shape::from([5]).rank(), 1);
    }

    #[test]
    fn strides_row_major() {
        assert_eq!(Shape::from([2, 3, 4]).strides(), vec![12, 4, 1]);
        assert_eq!(Shape::from([7]).strides(), vec![1]);
        assert_eq!(Shape::from(vec![]).strides(), Vec::<usize>::new());
    }

    #[test]
    fn zero_dim() {
        assert_eq!(Shape::from([0, 5]).numel(), 0);
    }

    #[test]
    #[should_panic]
    fn rows_requires_rank2() {
        Shape::from([3]).rows();
    }
}
