//! The microkernel layer: every band-parallel hot loop in the crate —
//! dense/conv forward GEMM, the §4/§6 fused `UᵀV` accumulations, the
//! backprop row dots, and the squared-norm reductions — bottoms out in
//! ONE of the five primitives on the [`Microkernel`] trait. Two
//! implementations exist:
//!
//! * [`ScalarKernel`] — the original scalar loops, moved here verbatim
//!   from `ops.rs` / the layer band kernels. This is the bitwise oracle:
//!   a `--features scalar-kernels` build reproduces pre-microkernel
//!   results bit for bit.
//! * [`PackedKernel`] — register-blocked f32 kernels over
//!   [`super::simd::F32x8`] lanes with thread-local panel packing of the
//!   B operand (and the transposed A panel for the `tn` kernel). The
//!   GEMM-shaped kernels preserve the scalar kernels' per-element
//!   accumulation ORDER (single accumulator, contraction index
//!   ascending), so they differ from the scalar oracle only through
//!   dropped `== 0.0` skips (a `c += 0.0 * b` contributes a signed
//!   zero); the reductions ([`Microkernel::row_sq`],
//!   [`Microkernel::dot_rows`]) use multi-lane partial sums and DO
//!   reassociate, within the tolerance band derived in the
//!   `tensor::ops` module docs.
//!
//! Dispatch: the `scalar-kernels` cargo feature pins [`active`] to the
//! scalar oracle at compile time; otherwise the `PEGRAD_KERNEL`
//! environment variable (`scalar` | `packed`, read once per process)
//! selects at startup, defaulting to packed. Band-parallel callers
//! resolve `active()` once and hand the `&'static dyn` to their worker
//! closures, so the per-band dispatch cost is one virtual call.

// The band kernels thread raw slices + explicit dims through fixed
// signatures shared with the original free functions; bundling them
// into structs would obscure the 1:1 mapping to the scalar oracle.
#![allow(clippy::too_many_arguments)]

use super::simd::{F32x8, LANES};

/// Cache-blocking factor of the scalar kernels (rows of B live in L1
/// across one block of the contraction index). Shared with
/// `ops::transpose`.
pub(crate) const BLOCK: usize = 64;

/// Register-tile rows of the packed GEMM (distinct broadcast operands
/// held across the k loop).
pub const MR: usize = 4;
/// Register-tile columns of the packed GEMM (two [`F32x8`] lanes).
pub const NR: usize = 2 * LANES;

/// The five primitives every dispatched hot loop reduces to. All are
/// plain slice kernels — banding/threading stays in the callers, so one
/// implementation serves serial and band-parallel paths identically.
pub trait Microkernel: Send + Sync {
    /// Kernel name (`"scalar"` / `"packed"`) for logs and bench tables.
    fn name(&self) -> &'static str;

    /// `C[i - r0, :] += Σ_kk A[i, kk] · B[kk, :]` for `i ∈ [r0, r1)`.
    /// `a` is the FULL `[*, k]` row-major matrix (absolute row indices),
    /// `c` is the band's `[(r1 - r0), n]` output chunk.
    fn matmul_band(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        r0: usize,
        r1: usize,
        k: usize,
        n: usize,
    );

    /// Fused §4/§6 transposed accumulation over `m` examples:
    /// `C[p - k0, :] += Σ_j coef[j] · A[j, p] · B[j, :]` for
    /// `p ∈ [k0, k1)`, with `a: [m, k]`, `b: [m, n]` row-major and
    /// `coef == None` meaning all-ones. A zero coefficient skips its
    /// example entirely (the §6 fully-clipped case).
    fn tn_band(
        &self,
        a: &[f32],
        b: &[f32],
        coef: Option<&[f32]>,
        c: &mut [f32],
        k0: usize,
        k1: usize,
        k: usize,
        n: usize,
        m: usize,
    );

    /// Row-batch of dot products: `out[p] = Σ_q v[q] · W[p, q]` where
    /// `w` holds `out.len()` rows of length `v.len()` (the backprop
    /// `δ·Wᵀ` inner loop and the conv `dx` patch dots).
    fn dot_rows(&self, v: &[f32], w: &[f32], out: &mut [f32]);

    /// `Σ x_i²` accumulated in f64 (the §4 norm reductions; shared by
    /// `row_sq_norms`/`sq_sum` and the streamed layer norms so bitwise
    /// couplings between them hold under either kernel).
    fn row_sq(&self, x: &[f32]) -> f64;
}

// ----------------------------------------------------------- dispatch trace

// Per-dispatch counters for the trace subsystem: kind, band rows
// processed, f32 bytes touched (operands + output). One relaxed-load
// branch when tracing is off; shared by both implementations so the
// counts are kernel-independent.

#[inline]
fn trace_matmul_band(r0: usize, r1: usize, k: usize, n: usize) {
    let rows = (r1 - r0) as u64;
    crate::trace::count_kernel(
        crate::trace::KernelKind::MatmulBand,
        rows,
        4 * (rows * k as u64 + (k * n) as u64 + rows * n as u64),
    );
}

#[inline]
fn trace_tn_band(k0: usize, k1: usize, k: usize, n: usize, m: usize) {
    let rows = (k1 - k0) as u64;
    crate::trace::count_kernel(
        crate::trace::KernelKind::TnBand,
        rows,
        4 * ((m * k) as u64 + (m * n) as u64 + rows * n as u64),
    );
}

#[inline]
fn trace_dot_rows(v_len: usize, rows: usize) {
    crate::trace::count_kernel(
        crate::trace::KernelKind::DotRows,
        rows as u64,
        4 * ((v_len + rows * v_len + rows) as u64),
    );
}

#[inline]
fn trace_row_sq(len: usize) {
    crate::trace::count_kernel(crate::trace::KernelKind::RowSq, 1, 4 * len as u64);
}

// --------------------------------------------------------------- scalar

/// The original scalar band kernels, verbatim (the bitwise oracle).
pub struct ScalarKernel;

impl Microkernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn matmul_band(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        r0: usize,
        r1: usize,
        k: usize,
        n: usize,
    ) {
        trace_matmul_band(r0, r1, k, n);
        for kb in (0..k).step_by(BLOCK) {
            let k_end = (kb + BLOCK).min(k);
            for i in r0..r1 {
                let c_row = &mut c[(i - r0) * n..(i - r0 + 1) * n];
                for kk in kb..k_end {
                    let aik = a[i * k + kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..kk * n + n];
                    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }

    fn tn_band(
        &self,
        a: &[f32],
        b: &[f32],
        coef: Option<&[f32]>,
        c: &mut [f32],
        k0: usize,
        k1: usize,
        k: usize,
        n: usize,
        m: usize,
    ) {
        trace_tn_band(k0, k1, k, n, m);
        for j in 0..m {
            let w = coef.map_or(1.0, |cf| cf[j]);
            if w == 0.0 {
                continue;
            }
            let a_row = &a[j * k..j * k + k];
            let b_row = &b[j * n..j * n + n];
            for p in k0..k1 {
                let apj = a_row[p];
                if apj == 0.0 {
                    continue;
                }
                let f = apj * w;
                let c_row = &mut c[(p - k0) * n..(p - k0 + 1) * n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += f * bv;
                }
            }
        }
    }

    fn dot_rows(&self, v: &[f32], w: &[f32], out: &mut [f32]) {
        trace_dot_rows(v.len(), out.len());
        let n = v.len();
        for (p, o) in out.iter_mut().enumerate() {
            let wrow = &w[p * n..(p + 1) * n];
            let mut dot = 0.0f32;
            for (&vv, &wv) in v.iter().zip(wrow) {
                dot += vv * wv;
            }
            *o = dot;
        }
    }

    fn row_sq(&self, x: &[f32]) -> f64 {
        trace_row_sq(x.len());
        let mut acc = 0.0f64;
        for &v in x {
            acc += (v as f64) * (v as f64);
        }
        acc
    }
}

// --------------------------------------------------------------- packed

/// Register-blocked kernels; see the module docs and `tensor::ops` for
/// the tiling/packing derivation.
pub struct PackedKernel;

thread_local! {
    // Panel scratch, per pool worker: packing buffers persist across
    // band calls so the steady state allocates nothing.
    static PACK_A: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
    static PACK_B: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

fn with_buf<R>(
    key: &'static std::thread::LocalKey<std::cell::RefCell<Vec<f32>>>,
    len: usize,
    f: impl FnOnce(&mut [f32]) -> R,
) -> R {
    key.with(|cell| {
        let mut v = cell.borrow_mut();
        if v.len() < len {
            v.resize(len, 0.0);
        }
        f(&mut v[..len])
    })
}

/// One register tile: `R` rows × [`NR`] columns of C held in `2R`
/// [`F32x8`] accumulators across the whole contraction loop. Per output
/// element this performs `acc = (acc + a·b)` with the contraction index
/// strictly ascending from the incoming C value — the same per-element
/// operation sequence as the scalar kernels (order preservation is what
/// keeps the packed matmul bitwise-aligned with the scalar oracle on
/// zero-free operands).
#[inline(always)]
fn tile16<const R: usize>(
    a: &[f32],
    lda: usize,
    pb: &[f32],
    coef: Option<&[f32]>,
    c: &mut [f32],
    ldc: usize,
    kdim: usize,
) {
    let mut acc = [[F32x8::splat(0.0); 2]; R];
    for r in 0..R {
        acc[r][0] = F32x8::load(&c[r * ldc..r * ldc + LANES]);
        acc[r][1] = F32x8::load(&c[r * ldc + LANES..r * ldc + NR]);
    }
    for t in 0..kdim {
        let w = match coef {
            Some(cf) => {
                let w = cf[t];
                if w == 0.0 {
                    continue;
                }
                Some(w)
            }
            None => None,
        };
        let bp = &pb[t * NR..t * NR + NR];
        let b0 = F32x8::load(&bp[..LANES]);
        let b1 = F32x8::load(&bp[LANES..]);
        for r in 0..R {
            let mut av = a[r * lda + t];
            if let Some(wv) = w {
                av *= wv;
            }
            let s = F32x8::splat(av);
            acc[r][0] = acc[r][0].add(s.mul(b0));
            acc[r][1] = acc[r][1].add(s.mul(b1));
        }
    }
    for r in 0..R {
        acc[r][0].store(&mut c[r * ldc..r * ldc + LANES]);
        acc[r][1].store(&mut c[r * ldc + LANES..r * ldc + NR]);
    }
}

/// Single-lane variant of [`tile16`] for the `LANES`-wide column tail.
#[inline(always)]
fn tile8<const R: usize>(
    a: &[f32],
    lda: usize,
    pb: &[f32],
    coef: Option<&[f32]>,
    c: &mut [f32],
    ldc: usize,
    kdim: usize,
) {
    let mut acc = [F32x8::splat(0.0); R];
    for r in 0..R {
        acc[r] = F32x8::load(&c[r * ldc..r * ldc + LANES]);
    }
    for t in 0..kdim {
        let w = match coef {
            Some(cf) => {
                let w = cf[t];
                if w == 0.0 {
                    continue;
                }
                Some(w)
            }
            None => None,
        };
        let b0 = F32x8::load(&pb[t * LANES..t * LANES + LANES]);
        for r in 0..R {
            let mut av = a[r * lda + t];
            if let Some(wv) = w {
                av *= wv;
            }
            acc[r] = acc[r].add(F32x8::splat(av).mul(b0));
        }
    }
    for r in 0..R {
        acc[r].store(&mut c[r * ldc..r * ldc + LANES]);
    }
}

/// Shared packed GEMM core:
/// `C[r, q] += Σ_t coef[t] · Ā[r, t] · B[t, q]` with `Ā` row-major under
/// leading dimension `lda` (the contraction index is always the
/// unit-stride axis of `Ā`, by construction of the two callers), `B`
/// row-major `[kdim, n]`, `C` row-major `[rows, n]`. B panels of NR
/// (then LANES) columns are packed contiguous so the inner loop streams
/// unit-stride; leftover columns run a scalar loop in the same
/// per-element order.
fn gemm_acc(
    a: &[f32],
    lda: usize,
    b: &[f32],
    coef: Option<&[f32]>,
    c: &mut [f32],
    rows: usize,
    n: usize,
    kdim: usize,
) {
    with_buf(&PACK_B, kdim * NR, |pb| {
        let mut q0 = 0;
        while q0 + NR <= n {
            for t in 0..kdim {
                pb[t * NR..t * NR + NR].copy_from_slice(&b[t * n + q0..t * n + q0 + NR]);
            }
            let mut r0 = 0;
            while r0 < rows {
                let rr = (rows - r0).min(MR);
                let ab = &a[r0 * lda..];
                let cb = &mut c[r0 * n + q0..];
                match rr {
                    4 => tile16::<4>(ab, lda, pb, coef, cb, n, kdim),
                    3 => tile16::<3>(ab, lda, pb, coef, cb, n, kdim),
                    2 => tile16::<2>(ab, lda, pb, coef, cb, n, kdim),
                    _ => tile16::<1>(ab, lda, pb, coef, cb, n, kdim),
                }
                r0 += rr;
            }
            q0 += NR;
        }
        if q0 + LANES <= n {
            for t in 0..kdim {
                pb[t * LANES..t * LANES + LANES]
                    .copy_from_slice(&b[t * n + q0..t * n + q0 + LANES]);
            }
            let mut r0 = 0;
            while r0 < rows {
                let rr = (rows - r0).min(MR);
                let ab = &a[r0 * lda..];
                let cb = &mut c[r0 * n + q0..];
                match rr {
                    4 => tile8::<4>(ab, lda, pb, coef, cb, n, kdim),
                    3 => tile8::<3>(ab, lda, pb, coef, cb, n, kdim),
                    2 => tile8::<2>(ab, lda, pb, coef, cb, n, kdim),
                    _ => tile8::<1>(ab, lda, pb, coef, cb, n, kdim),
                }
                r0 += rr;
            }
            q0 += LANES;
        }
        if q0 < n {
            for r in 0..rows {
                let arow = &a[r * lda..r * lda + kdim];
                for q in q0..n {
                    let mut acc = c[r * n + q];
                    for (t, &av) in arow.iter().enumerate() {
                        let f = match coef {
                            Some(cf) => {
                                let w = cf[t];
                                if w == 0.0 {
                                    continue;
                                }
                                av * w
                            }
                            None => av,
                        };
                        acc += f * b[t * n + q];
                    }
                    c[r * n + q] = acc;
                }
            }
        }
    });
}

impl Microkernel for PackedKernel {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn matmul_band(
        &self,
        a: &[f32],
        b: &[f32],
        c: &mut [f32],
        r0: usize,
        r1: usize,
        k: usize,
        n: usize,
    ) {
        trace_matmul_band(r0, r1, k, n);
        gemm_acc(&a[r0 * k..r1 * k], k, b, None, c, r1 - r0, n, k);
    }

    fn tn_band(
        &self,
        a: &[f32],
        b: &[f32],
        coef: Option<&[f32]>,
        c: &mut [f32],
        k0: usize,
        k1: usize,
        k: usize,
        n: usize,
        m: usize,
    ) {
        trace_tn_band(k0, k1, k, n, m);
        let rows = k1 - k0;
        with_buf(&PACK_A, rows * m, |at| {
            // pack the band's A columns transposed (the "A panel"): the
            // scalar kernel's stride-k column walk becomes unit-stride
            // panel rows, and the GEMM core contracts over j ascending —
            // the same per-element order as the scalar j-outer loop.
            for j in 0..m {
                let arow = &a[j * k..j * k + k];
                for p in k0..k1 {
                    at[(p - k0) * m + j] = arow[p];
                }
            }
            gemm_acc(at, m, b, coef, c, rows, n, m);
        });
    }

    fn dot_rows(&self, v: &[f32], w: &[f32], out: &mut [f32]) {
        trace_dot_rows(v.len(), out.len());
        let n = v.len();
        let split = n - n % LANES;
        for (p, o) in out.iter_mut().enumerate() {
            let wrow = &w[p * n..(p + 1) * n];
            let mut acc = F32x8::splat(0.0);
            let mut q = 0;
            while q + LANES <= n {
                acc = acc.add(F32x8::load(&v[q..q + LANES]).mul(F32x8::load(&wrow[q..q + LANES])));
                q += LANES;
            }
            let mut dot = acc.hsum();
            for (&vv, &wv) in v[split..].iter().zip(&wrow[split..]) {
                dot += vv * wv;
            }
            *o = dot;
        }
    }

    fn row_sq(&self, x: &[f32]) -> f64 {
        trace_row_sq(x.len());
        let mut acc = [0.0f64; LANES];
        let mut chunks = x.chunks_exact(LANES);
        for ch in chunks.by_ref() {
            for (a, &v) in acc.iter_mut().zip(ch) {
                let vd = v as f64;
                *a += vd * vd;
            }
        }
        for (a, &v) in acc.iter_mut().zip(chunks.remainder()) {
            let vd = v as f64;
            *a += vd * vd;
        }
        acc.iter().sum()
    }
}

// ------------------------------------------------------------- dispatch

/// The scalar oracle instance.
pub static SCALAR: ScalarKernel = ScalarKernel;
/// The packed instance (always compiled, so benches/tests can compare
/// the two regardless of the active dispatch).
pub static PACKED: PackedKernel = PackedKernel;

/// The kernel every dispatched op routes through.
#[cfg(feature = "scalar-kernels")]
pub fn active() -> &'static dyn Microkernel {
    &SCALAR
}

/// The kernel every dispatched op routes through: `PEGRAD_KERNEL`
/// (`scalar` | `packed`), read once per process, defaulting to packed.
#[cfg(not(feature = "scalar-kernels"))]
pub fn active() -> &'static dyn Microkernel {
    use once_cell::sync::Lazy;
    static ACTIVE: Lazy<&'static dyn Microkernel> =
        Lazy::new(|| match std::env::var("PEGRAD_KERNEL").as_deref() {
            Ok("scalar") => &SCALAR,
            Ok("packed") | Err(_) => &PACKED,
            Ok(other) => {
                log::warn!("PEGRAD_KERNEL={other:?} not one of scalar|packed; using packed");
                &PACKED
            }
        });
    *ACTIVE
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randn_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.next_normal()).collect()
    }

    #[test]
    fn active_dispatch_is_consistent() {
        let k = active();
        #[cfg(feature = "scalar-kernels")]
        assert_eq!(k.name(), "scalar");
        #[cfg(not(feature = "scalar-kernels"))]
        assert!(k.name() == "scalar" || k.name() == "packed");
    }

    /// Order preservation: on zero-free operands the packed GEMM kernels
    /// are BITWISE equal to the scalar oracle (same per-element
    /// accumulation sequence; only `== 0.0` skips can diverge, by a
    /// signed zero). Randn operands are zero-free with probability 1.
    #[test]
    fn packed_matmul_band_bitwise_on_zero_free_operands() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 8, 16), (5, 3, 21), (7, 129, 37)] {
            let a = randn_vec(m * k, &mut rng);
            let b = randn_vec(k * n, &mut rng);
            let mut cs = vec![0.0f32; m * n];
            let mut cp = vec![0.0f32; m * n];
            SCALAR.matmul_band(&a, &b, &mut cs, 0, m, k, n);
            PACKED.matmul_band(&a, &b, &mut cp, 0, m, k, n);
            assert_eq!(cs, cp, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn packed_tn_band_bitwise_with_coef() {
        let mut rng = Rng::new(12);
        for &(m, k, n) in &[(3usize, 5usize, 9usize), (8, 16, 16), (6, 31, 18)] {
            let a = randn_vec(m * k, &mut rng);
            let b = randn_vec(m * n, &mut rng);
            // coefficient vector with explicit zeros: both kernels skip
            // those examples outright, so bitwise equality still holds
            let coef: Vec<f32> =
                (0..m).map(|j| if j % 3 == 0 { 0.0 } else { 0.5 + j as f32 }).collect();
            for co in [None, Some(coef.as_slice())] {
                let mut cs = vec![0.0f32; k * n];
                let mut cp = vec![0.0f32; k * n];
                SCALAR.tn_band(&a, &b, co, &mut cs, 0, k, k, n, m);
                PACKED.tn_band(&a, &b, co, &mut cp, 0, k, k, n, m);
                assert_eq!(cs, cp, "m={m} k={k} n={n} coef={}", co.is_some());
            }
        }
    }

    #[test]
    fn packed_reductions_within_tolerance() {
        let mut rng = Rng::new(13);
        for &n in &[1usize, 7, 8, 9, 63, 64, 65, 1000] {
            let x = randn_vec(n, &mut rng);
            let s = SCALAR.row_sq(&x);
            let p = PACKED.row_sq(&x);
            assert!(
                (s - p).abs() <= 1e-9 * s.abs().max(1.0),
                "n={n}: scalar {s} packed {p}"
            );
        }
        let v = randn_vec(37, &mut rng);
        let w = randn_vec(5 * 37, &mut rng);
        let mut os = [0.0f32; 5];
        let mut op = [0.0f32; 5];
        SCALAR.dot_rows(&v, &w, &mut os);
        PACKED.dot_rows(&v, &w, &mut op);
        for (a, b) in os.iter().zip(op) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
    }
}
