//! Fixed-width f32 lane arithmetic for the packed microkernels.
//!
//! The vendored registry has no `wide`/`packed_simd`, and baseline
//! x86-64 has no guaranteed FMA, so this module is deliberately plain:
//! a `[f32; 8]` value type whose lane-wise `add`/`mul` loops LLVM
//! auto-vectorizes into SSE/AVX at `opt-level >= 2`. Eight lanes is one
//! AVX register (or two SSE registers) — wide enough to saturate the
//! FP pipes, narrow enough that a 4×16 register tile (8 accumulators)
//! plus operands fits the 16 architectural vector registers.
//!
//! Two rules keep the packed kernels numerically honest
//! (see `tensor::ops` module docs for the full argument):
//!
//! * **No `mul_add`.** Baseline targets lower it to a libm call, and a
//!   fused multiply-add would change the per-element rounding relative
//!   to the scalar oracle. `add(a.mul(b))` keeps the exact
//!   multiply-then-add sequence the scalar kernels perform.
//! * **In-order horizontal sums.** [`F32x8::hsum`] folds lanes
//!   left-to-right so reductions stay deterministic across runs and
//!   thread counts.

/// Lane count of the packed kernels' vector type.
pub const LANES: usize = 8;

/// Eight f32 lanes; a plain value type the optimizer keeps in one
/// vector register.
#[derive(Debug, Clone, Copy)]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    #[inline(always)]
    /// All eight lanes set to `v`.
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Load the first [`LANES`] values of `s`.
    #[inline(always)]
    pub fn load(s: &[f32]) -> F32x8 {
        let mut v = [0.0f32; LANES];
        v.copy_from_slice(&s[..LANES]);
        F32x8(v)
    }

    /// Store into the first [`LANES`] slots of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..LANES].copy_from_slice(&self.0);
    }

    #[inline(always)]
    /// Lanewise addition.
    pub fn add(mut self, o: F32x8) -> F32x8 {
        for (a, b) in self.0.iter_mut().zip(o.0) {
            *a += b;
        }
        self
    }

    #[inline(always)]
    /// Lanewise multiplication.
    pub fn mul(mut self, o: F32x8) -> F32x8 {
        for (a, b) in self.0.iter_mut().zip(o.0) {
            *a *= b;
        }
        self
    }

    /// Left-to-right horizontal sum (deterministic lane order).
    #[inline(always)]
    pub fn hsum(self) -> f32 {
        let mut s = 0.0f32;
        for v in self.0 {
            s += v;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_ops_elementwise() {
        let a = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(2.0);
        assert_eq!(a.add(b).0, [3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        assert_eq!(a.mul(b).0, [2.0, 4.0, 6.0, 8.0, 10.0, 12.0, 14.0, 16.0]);
        assert_eq!(a.hsum(), 36.0);
    }

    #[test]
    fn load_store_roundtrip() {
        let src = [1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0, 99.0];
        let v = F32x8::load(&src);
        let mut dst = [0.0f32; 10];
        v.store(&mut dst);
        assert_eq!(&dst[..8], &src[..8]);
        assert_eq!(dst[8], 0.0, "store must touch exactly LANES slots");
    }

    #[test]
    fn hsum_is_left_to_right() {
        // a lane order-dependent case: (big + tiny) loses the tiny bit,
        // so the left-to-right spec pins which partials absorb which
        let v = F32x8([1e8, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let mut want = 0.0f32;
        for x in v.0 {
            want += x;
        }
        assert_eq!(v.hsum(), want);
    }
}
