//! Tensor operations: elementwise, reductions, activations, and a blocked
//! cache-friendly parallel matmul.
//!
//! The matmul family is the performance-relevant part — it backs the rust
//! reference implementation used as the E1/E2 CPU baseline — so it gets a
//! blocked i-k-j loop order (unit-stride inner loop, FMA-friendly) and
//! row-band parallelism over the global thread pool.

use crate::util::threadpool;

use super::Tensor;

// ---------------------------------------------------------------------------
// Elementwise
// ---------------------------------------------------------------------------

fn zip(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.dims(), b.dims(), "elementwise shape mismatch");
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| f(x, y))
        .collect();
    Tensor::new(a.dims().to_vec(), data)
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x + y)
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x - y)
}

pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x * y)
}

pub fn map(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(a.dims().to_vec(), a.data().iter().map(|&x| f(x)).collect())
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    map(a, |x| x * s)
}

/// a += s * b (in place; the optimizer hot path).
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.dims(), b.dims());
    for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * y;
    }
}

/// Scale each row i of a rank-2 tensor by coef[i] (the §6 rescale).
pub fn scale_rows(a: &Tensor, coef: &[f32]) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert_eq!(coef.len(), m);
    let mut out = a.clone();
    for i in 0..m {
        let c = coef[i];
        for v in &mut out.data_mut()[i * n..(i + 1) * n] {
            *v *= c;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------------

pub fn sum(a: &Tensor) -> f32 {
    a.data().iter().sum()
}

pub fn mean(a: &Tensor) -> f32 {
    sum(a) / a.numel() as f32
}

/// Sum of squares of every element (||a||_F^2).
pub fn sq_sum(a: &Tensor) -> f64 {
    a.data().iter().map(|&x| (x as f64) * (x as f64)).sum()
}

/// Row-wise sum of squares of a rank-2 tensor — the paper's O(mp) kernel,
/// rust reference version (f64 accumulator mirrors the f32-accumulate
/// Pallas kernel closely enough at our scales).
pub fn row_sq_norms(a: &Tensor) -> Vec<f32> {
    let m = a.dims()[0];
    let mut out = vec![0f32; m];
    for i in 0..m {
        let mut acc = 0f64;
        for &v in a.row(i) {
            acc += (v as f64) * (v as f64);
        }
        out[i] = acc as f32;
    }
    out
}

/// argmax per row (classification accuracy).
pub fn row_argmax(a: &Tensor) -> Vec<usize> {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    (0..m)
        .map(|i| {
            let row = a.row(i);
            let mut best = 0;
            for j in 1..n {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Activations (phi) and their derivatives
// ---------------------------------------------------------------------------

/// Activation kind; mirrors `python/compile/model.py::ACTIVATIONS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
    Gelu,
    Sigmoid,
    Identity,
}

impl Activation {
    pub fn parse(s: &str) -> Option<Activation> {
        Some(match s {
            "relu" => Activation::Relu,
            "tanh" => Activation::Tanh,
            "gelu" => Activation::Gelu,
            "sigmoid" => Activation::Sigmoid,
            "identity" => Activation::Identity,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
            Activation::Gelu => "gelu",
            Activation::Sigmoid => "sigmoid",
            Activation::Identity => "identity",
        }
    }

    pub fn apply(&self, z: f32) -> f32 {
        match self {
            Activation::Relu => z.max(0.0),
            Activation::Tanh => z.tanh(),
            Activation::Gelu => gelu(z),
            Activation::Sigmoid => sigmoid(z),
            Activation::Identity => z,
        }
    }

    /// dphi/dz.
    pub fn grad(&self, z: f32) -> f32 {
        match self {
            Activation::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => {
                let t = z.tanh();
                1.0 - t * t
            }
            Activation::Gelu => gelu_grad(z),
            Activation::Sigmoid => {
                let s = sigmoid(z);
                s * (1.0 - s)
            }
            Activation::Identity => 1.0,
        }
    }
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Exact (erf-free approximation-free) gelu via tanh form used by jax.nn.gelu
/// (approximate=True is jax's default).
fn gelu(z: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * z * (1.0 + (C * (z + 0.044715 * z * z * z)).tanh())
}

fn gelu_grad(z: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let u = C * (z + 0.044715 * z * z * z);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
}

// ---------------------------------------------------------------------------
// Softmax / log-softmax (rowwise, numerically stable)
// ---------------------------------------------------------------------------

pub fn log_softmax_rows(a: &Tensor) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = a.clone();
    for i in 0..m {
        let row = &mut out.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>().ln() as f32 + mx;
        for v in row {
            *v -= lse;
        }
    }
    out
}

pub fn softmax_rows(a: &Tensor) -> Tensor {
    map(&log_softmax_rows(a), f32::exp)
}

// ---------------------------------------------------------------------------
// Matmul family
// ---------------------------------------------------------------------------

/// Tile edge for the blocked matmul (f32: 64*64*4B = 16KiB per tile pair —
/// comfortably L1/L2 resident).
const BLOCK: usize = 64;
/// Below this many output elements the parallel dispatch overhead wins.
const PAR_THRESHOLD: usize = 64 * 64 * 4;

/// C = A @ B for rank-2 tensors.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
    let mut out = Tensor::zeros(vec![m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// C = A^T @ B where A is [m, k], B is [m, n] -> C [k, n].
/// This is the §6 `Wbar = Haug^T Zbar` recompute, rust reference version.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (m2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(m, m2, "matmul_tn contraction dim: {m} vs {m2}");
    // Transpose A once (k*m writes) then reuse the blocked kernel: for the
    // sizes we care about this beats a strided kernel.
    let at = transpose(a);
    let mut out = Tensor::zeros(vec![k, n]);
    matmul_into(at.data(), b.data(), out.data_mut(), k, m, n);
    out
}

/// C = A @ B^T where A is [m, k], B is [n, k] -> C [m, n].
/// This is the backprop `dH = Zbar @ W^T` step.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_nt inner dim: {k} vs {k2}");
    let bt = transpose(b);
    let mut out = Tensor::zeros(vec![m, n]);
    matmul_into(a.data(), bt.data(), out.data_mut(), m, k, n);
    out
}

pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = Tensor::zeros(vec![n, m]);
    // Blocked transpose for cache behaviour on large matrices.
    let od = out.data_mut();
    let ad = a.data();
    for ib in (0..m).step_by(BLOCK) {
        for jb in (0..n).step_by(BLOCK) {
            for i in ib..(ib + BLOCK).min(m) {
                for j in jb..(jb + BLOCK).min(n) {
                    od[j * m + i] = ad[i * n + j];
                }
            }
        }
    }
    out
}

/// Blocked i-k-j kernel over a row band [r0, r1).
fn matmul_band(a: &[f32], b: &[f32], c: &mut [f32], r0: usize, r1: usize, k: usize, n: usize) {
    for kb in (0..k).step_by(BLOCK) {
        let k_end = (kb + BLOCK).min(k);
        for i in r0..r1 {
            let c_row = &mut c[(i - r0) * n..(i - r0 + 1) * n];
            for kk in kb..k_end {
                let aik = a[i * k + kk];
                if aik == 0.0 {
                    continue; // relu sparsity win in the reference impl
                }
                let b_row = &b[kk * n..kk * n + n];
                for (cv, &bv) in c_row.iter_mut().zip(b_row) {
                    *cv += aik * bv;
                }
            }
        }
    }
}

fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    if m * n <= PAR_THRESHOLD || m == 1 {
        matmul_band(a, b, c, 0, m, k, n);
        return;
    }
    let pool = threadpool::global();
    let bands = pool.size().min(m);
    let rows_per = m.div_ceil(bands);
    // Workers write into disjoint row bands; assemble after.
    let a_arc: std::sync::Arc<Vec<f32>> = std::sync::Arc::new(a.to_vec());
    let b_arc: std::sync::Arc<Vec<f32>> = std::sync::Arc::new(b.to_vec());
    let parts = pool.scope_map(bands, move |band| {
        let r0 = band * rows_per;
        let r1 = ((band + 1) * rows_per).min(m);
        let mut part = vec![0f32; (r1.saturating_sub(r0)) * n];
        if r0 < r1 {
            matmul_band(&a_arc, &b_arc, &mut part, r0, r1, k, n);
        }
        part
    });
    let mut off = 0;
    for part in parts {
        c[off..off + part.len()].copy_from_slice(&part);
        off += part.len();
    }
}

/// Append the constant-1 bias column (paper §2's augmented h).
pub fn augment(h: &Tensor) -> Tensor {
    let (m, n) = (h.dims()[0], h.dims()[1]);
    let mut out = Tensor::zeros(vec![m, n + 1]);
    for i in 0..m {
        out.data_mut()[i * (n + 1)..i * (n + 1) + n].copy_from_slice(h.row(i));
        out.data_mut()[i * (n + 1) + n] = 1.0;
    }
    out
}

/// Drop the last column (inverse of `augment` for gradient flow).
pub fn drop_last_col(h: &Tensor) -> Tensor {
    let (m, n1) = (h.dims()[0], h.dims()[1]);
    let n = n1 - 1;
    let mut out = Tensor::zeros(vec![m, n]);
    for i in 0..m {
        out.data_mut()[i * n..(i + 1) * n].copy_from_slice(&h.row(i)[..n]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::util::prop;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut c = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += (a.at2(i, kk) as f64) * (b.at2(kk, j) as f64);
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_prop() {
        prop::check(25, |g| {
            let (m, k, n) = (
                g.usize_in(1..40),
                g.usize_in(1..40),
                g.usize_in(1..40),
            );
            let mut rng = Rng::new(g.case);
            let a = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![k, n], &mut rng);
            prop::assert_all_close(matmul(&a, &b).data(), naive_matmul(&a, &b).data(), 1e-3)
        });
    }

    #[test]
    fn matmul_parallel_path() {
        // Big enough to cross PAR_THRESHOLD.
        let mut rng = Rng::new(0);
        let a = Tensor::randn(vec![200, 120], &mut rng);
        let b = Tensor::randn(vec![120, 150], &mut rng);
        let got = matmul(&a, &b);
        let want = naive_matmul(&a, &b);
        prop::assert_all_close(got.data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn matmul_tn_and_nt() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(vec![12, 7], &mut rng);
        let b = Tensor::randn(vec![12, 9], &mut rng);
        let want = naive_matmul(&transpose(&a), &b);
        prop::assert_all_close(matmul_tn(&a, &b).data(), want.data(), 1e-3).unwrap();

        let c = Tensor::randn(vec![5, 7], &mut rng);
        let d = Tensor::randn(vec![9, 7], &mut rng);
        let want = naive_matmul(&c, &transpose(&d));
        prop::assert_all_close(matmul_nt(&c, &d).data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(vec![33, 71], &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn row_sq_norms_basic() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 2.0, 0.0, -3.0, 4.0]);
        assert_eq!(row_sq_norms(&t), vec![9.0, 25.0]);
    }

    #[test]
    fn augment_and_drop() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let a = augment(&t);
        assert_eq!(a.dims(), &[2, 3]);
        assert_eq!(a.row(0), &[1.0, 2.0, 1.0]);
        assert_eq!(drop_last_col(&a), t);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(vec![4, 9], &mut rng);
        let s = softmax_rows(&t);
        for i in 0..4 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_stable_at_large_logits() {
        let t = Tensor::new(vec![1, 3], vec![1000.0, 1000.0, 1000.0]);
        let ls = log_softmax_rows(&t);
        for &v in ls.data() {
            assert!((v - (-(3f32).ln())).abs() < 1e-4);
        }
    }

    #[test]
    fn activations_match_finite_difference() {
        prop::check(40, |g| {
            let act = *g.choose(&[
                Activation::Relu,
                Activation::Tanh,
                Activation::Gelu,
                Activation::Sigmoid,
                Activation::Identity,
            ]);
            let z = g.f32_in(-3.0..3.0);
            if matches!(act, Activation::Relu) && z.abs() < 1e-2 {
                return Ok(()); // kink
            }
            let h = 1e-3f32;
            let fd = (act.apply(z + h) - act.apply(z - h)) / (2.0 * h);
            prop::assert_close(act.grad(z) as f64, fd as f64, 5e-2)
        });
    }

    #[test]
    fn activation_parse_roundtrip() {
        for name in ["relu", "tanh", "gelu", "sigmoid", "identity"] {
            assert_eq!(Activation::parse(name).unwrap().name(), name);
        }
        assert!(Activation::parse("swish").is_none());
    }

    #[test]
    fn scale_rows_matches_manual() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let s = scale_rows(&t, &[2.0, 0.5]);
        assert_eq!(s.data(), &[2.0, 4.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_in_place() {
        let mut a = Tensor::ones(vec![3]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        axpy(&mut a, -0.5, &b);
        assert_eq!(a.data(), &[0.5, 0.0, -0.5]);
    }

    #[test]
    fn row_argmax_ties_first() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 3.0, 3.0, 5.0, 2.0, 1.0]);
        assert_eq!(row_argmax(&t), vec![1, 0]);
    }
}
