//! Tensor operations: elementwise, reductions, activations, and the
//! dispatched matmul family.
//!
//! The matmul family is the performance-relevant part — it backs the rust
//! reference implementation used as the E1/E2 CPU baseline and the fused
//! engine's kernels. Banding/threading lives HERE (row-band parallelism
//! dispatched onto the persistent worker pool via [`threadpool::scope`],
//! jobs borrowing the operands directly, band count from
//! [`threadpool::bands`]); the per-band inner loops live in
//! [`super::kernels`] behind the [`super::kernels::Microkernel`] trait,
//! with a scalar oracle and a packed register-blocked implementation.
//!
//! # Packing / tiling scheme (the packed kernel)
//!
//! All three GEMM-shaped hot loops (`matmul_into`, the §4/§6 fused
//! `tn` accumulation, and the implicit-conv forward, which reuses
//! `matmul_band` on gathered patch rows) share one core: an `MR×NR` =
//! 4×16 register tile of C held in eight 8-lane accumulators across the
//! entire contraction loop. Per contraction index `t` the kernel
//! broadcasts one A element per tile row (`splat`) and streams two
//! unit-stride lanes of B, so each C element costs 2 memory touches per
//! `4·16` multiply-adds instead of the scalar kernel's
//! load-modify-store of the whole C row per `(i, t)` pair — that
//! arithmetic-intensity jump (C traffic divided by `MR`, B traffic
//! amortized across the tile) is where the ≥2× single-thread gate in
//! `benches/e13_kernel.rs` comes from. B panels of NR columns are
//! packed contiguous per panel (thread-local scratch, amortized across
//! all row tiles of the band) so the inner loop reads one dense stream;
//! for the `tn` kernel the band's A columns are packed transposed once
//! per call, turning its stride-`k` column walk into unit-stride panel
//! rows. Column remainders fall to an 8-wide tile, then a scalar tail;
//! row remainders monomorphize the tile height (`R ∈ {1,2,3,4}`).
//!
//! # Why the error is bounded (the tolerance argument)
//!
//! The packed GEMM kernels do NOT reassociate: each output element
//! keeps a single accumulator and adds `a·b` terms with the contraction
//! index strictly ascending — the same per-element operation sequence
//! as the scalar oracle (and no `mul_add`, so per-term rounding is
//! identical too). Their only divergence from the scalar path is the
//! dropped `== 0.0` sparsity skips, which can flip a `-0.0` to `+0.0`
//! (`x + 0.0·b`); on finite data the values are otherwise bit-equal,
//! which is what keeps the implicit-conv-vs-im2col and
//! streamed-vs-materialized bitwise test couplings intact under the
//! packed dispatch. The REDUCTIONS do reassociate: `row_sq` folds into
//! 8 f64 partial sums (error for n terms bounded by `~log₂(8)·n·ε_f64`
//! of the running magnitude before the f32 round — many orders below
//! the f32 quantum, so the f32 results virtually always agree bit for
//! bit), and `dot_rows` folds f32 products into 8 f32 lanes + an
//! in-order horizontal sum: a classic forward-error bound of
//! `(n/8 + 8)·ε_f32·Σ|v_q·w_q|` vs the scalar dot's `n·ε_f32` — same
//! magnitude, different grouping, hence the documented relative band of
//! `1e-4` (`tests/kernels.rs`) on normalized data rather than bitwise
//! equality. Everything bitwise-coupled across code paths routes
//! through the SAME dispatched primitive, so those couplings are
//! kernel-independent by construction.

use crate::util::threadpool;

use super::kernels;
use super::Tensor;

// ---------------------------------------------------------------------------
// Elementwise
// ---------------------------------------------------------------------------

fn zip(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.dims(), b.dims(), "elementwise shape mismatch");
    let data = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| f(x, y))
        .collect();
    Tensor::new(a.dims().to_vec(), data)
}

/// Elementwise `a + b` (shapes must match).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x + y)
}

/// Elementwise `a - b` (shapes must match).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x - y)
}

/// Elementwise `a * b` (shapes must match).
pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip(a, b, |x, y| x * y)
}

/// Elementwise map of `f` over `a` into a new tensor.
pub fn map(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::new(a.dims().to_vec(), a.data().iter().map(|&x| f(x)).collect())
}

/// Every element of `a` scaled by `s`.
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    map(a, |x| x * s)
}

/// a += s * b (in place; the optimizer hot path).
pub fn axpy(a: &mut Tensor, s: f32, b: &Tensor) {
    assert_eq!(a.dims(), b.dims());
    for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
        *x += s * y;
    }
}

/// Scale each row i of a rank-2 tensor by coef[i] (the §6 rescale).
pub fn scale_rows(a: &Tensor, coef: &[f32]) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert_eq!(coef.len(), m);
    let mut out = a.clone();
    for i in 0..m {
        let c = coef[i];
        for v in &mut out.data_mut()[i * n..(i + 1) * n] {
            *v *= c;
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------------

/// Sum of all elements, in storage order.
pub fn sum(a: &Tensor) -> f32 {
    a.data().iter().sum()
}

/// Mean of all elements.
pub fn mean(a: &Tensor) -> f32 {
    sum(a) / a.numel() as f32
}

/// Sum of squares of every element (||a||_F^2).
///
/// Dispatched through [`kernels::active`] so every `sq_sum`-vs-streamed
/// bitwise coupling in the test suite compares like with like whichever
/// kernel is selected.
pub fn sq_sum(a: &Tensor) -> f64 {
    kernels::active().row_sq(a.data())
}

/// Row-wise sum of squares of a rank-2 tensor — the paper's O(mp) kernel,
/// rust reference version (f64 accumulator mirrors the f32-accumulate
/// Pallas kernel closely enough at our scales). Dispatched per row through
/// [`kernels::active`].
pub fn row_sq_norms(a: &Tensor) -> Vec<f32> {
    let m = a.dims()[0];
    let kern = kernels::active();
    let mut out = vec![0f32; m];
    for (i, o) in out.iter_mut().enumerate() {
        *o = kern.row_sq(a.row(i)) as f32;
    }
    out
}

/// argmax per row (classification accuracy).
pub fn row_argmax(a: &Tensor) -> Vec<usize> {
    row_argmax_rows(a.data(), a.dims()[0], a.dims()[1])
}

/// [`row_argmax`] on a raw row-major slice of `m` rows of width `n`.
pub fn row_argmax_rows(a: &[f32], m: usize, n: usize) -> Vec<usize> {
    debug_assert_eq!(a.len(), m * n);
    (0..m)
        .map(|i| {
            let row = &a[i * n..(i + 1) * n];
            let mut best = 0;
            for j in 1..n {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Activations (phi) and their derivatives
// ---------------------------------------------------------------------------

/// Activation kind; mirrors `python/compile/model.py::ACTIVATIONS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// `max(z, 0)`.
    Relu,
    /// Hyperbolic tangent.
    Tanh,
    /// Tanh-approximation GELU.
    Gelu,
    /// Logistic sigmoid.
    Sigmoid,
    /// Pass-through (linear output layers).
    Identity,
}

impl Activation {
    /// Parse an activation name (`"relu"`, `"tanh"`, …); `None` if unknown.
    pub fn parse(s: &str) -> Option<Activation> {
        Some(match s {
            "relu" => Activation::Relu,
            "tanh" => Activation::Tanh,
            "gelu" => Activation::Gelu,
            "sigmoid" => Activation::Sigmoid,
            "identity" => Activation::Identity,
            _ => return None,
        })
    }

    /// The canonical name [`Activation::parse`] accepts.
    pub fn name(&self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Tanh => "tanh",
            Activation::Gelu => "gelu",
            Activation::Sigmoid => "sigmoid",
            Activation::Identity => "identity",
        }
    }

    /// phi(z).
    pub fn apply(&self, z: f32) -> f32 {
        match self {
            Activation::Relu => z.max(0.0),
            Activation::Tanh => z.tanh(),
            Activation::Gelu => gelu(z),
            Activation::Sigmoid => sigmoid(z),
            Activation::Identity => z,
        }
    }

    /// dphi/dz.
    pub fn grad(&self, z: f32) -> f32 {
        match self {
            Activation::Relu => {
                if z > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => {
                let t = z.tanh();
                1.0 - t * t
            }
            Activation::Gelu => gelu_grad(z),
            Activation::Sigmoid => {
                let s = sigmoid(z);
                s * (1.0 - s)
            }
            Activation::Identity => 1.0,
        }
    }
}

fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Exact (erf-free approximation-free) gelu via tanh form used by jax.nn.gelu
/// (approximate=True is jax's default).
fn gelu(z: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * z * (1.0 + (C * (z + 0.044715 * z * z * z)).tanh())
}

fn gelu_grad(z: f32) -> f32 {
    const C: f32 = 0.7978845608;
    let u = C * (z + 0.044715 * z * z * z);
    let t = u.tanh();
    let du = C * (1.0 + 3.0 * 0.044715 * z * z);
    0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * du
}

// ---------------------------------------------------------------------------
// Softmax / log-softmax (rowwise, numerically stable)
// ---------------------------------------------------------------------------

/// Row-wise log-softmax of a rank-2 tensor (max-shifted, f64 log-sum-exp).
pub fn log_softmax_rows(a: &Tensor) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = a.clone();
    for i in 0..m {
        let row = &mut out.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>().ln() as f32 + mx;
        for v in row {
            *v -= lse;
        }
    }
    out
}

/// Row-wise softmax of a rank-2 tensor (via [`log_softmax_rows`]).
pub fn softmax_rows(a: &Tensor) -> Tensor {
    map(&log_softmax_rows(a), f32::exp)
}

// ---------------------------------------------------------------------------
// Matmul family
// ---------------------------------------------------------------------------

pub(crate) use super::kernels::BLOCK;
/// Below this many output elements the parallel dispatch overhead wins.
const PAR_THRESHOLD: usize = 64 * 64 * 4;

/// C = A @ B for rank-2 tensors.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (k2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
    let mut out = Tensor::zeros(vec![m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// C = A^T @ B where A is [m, k], B is [m, n] -> C [k, n].
/// This is the §6 `Wbar = Haug^T Zbar` recompute, rust reference version.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (m2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(m, m2, "matmul_tn contraction dim: {m} vs {m2}");
    // Transpose A once (k*m writes) then reuse the blocked kernel: for the
    // sizes we care about this beats a strided kernel.
    let at = transpose(a);
    let mut out = Tensor::zeros(vec![k, n]);
    matmul_into(at.data(), b.data(), out.data_mut(), k, m, n);
    out
}

/// C = A @ B^T where A is [m, k], B is [n, k] -> C [m, n].
/// This is the backprop `dH = Zbar @ W^T` step.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "matmul_nt inner dim: {k} vs {k2}");
    let bt = transpose(b);
    let mut out = Tensor::zeros(vec![m, n]);
    matmul_into(a.data(), bt.data(), out.data_mut(), m, k, n);
    out
}

/// Transpose of a rank-2 tensor (materialized, cache-blocked copy).
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    let mut out = Tensor::zeros(vec![n, m]);
    // Blocked transpose for cache behaviour on large matrices.
    let od = out.data_mut();
    let ad = a.data();
    for ib in (0..m).step_by(BLOCK) {
        for jb in (0..n).step_by(BLOCK) {
            for i in ib..(ib + BLOCK).min(m) {
                for j in jb..(jb + BLOCK).min(n) {
                    od[j * m + i] = ad[i * n + j];
                }
            }
        }
    }
    out
}

/// Accumulating blocked matmul over row bands. The pooled workers borrow
/// the operands directly — no input cloning, no output assembly copy
/// (each band job owns a disjoint `chunks_mut` band of `c`), and the
/// dispatch reuses the persistent [`threadpool`] workers instead of
/// spawning threads per call; the only per-call cost is one small job box
/// per band. (The original implementation Arc-copied both inputs per
/// call; at engine batch sizes that was the dominant allocation.)
fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let kern = kernels::active();
    if m * n <= PAR_THRESHOLD || m == 1 {
        kern.matmul_band(a, b, c, 0, m, k, n);
        return;
    }
    let bands = threadpool::bands().min(m);
    let rows_per = m.div_ceil(bands);
    let jobs: Vec<threadpool::ScopedJob> = c
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(bi, chunk)| {
            let r0 = bi * rows_per;
            let r1 = r0 + chunk.len() / n;
            Box::new(move || kern.matmul_band(a, b, chunk, r0, r1, k, n))
                as threadpool::ScopedJob
        })
        .collect();
    threadpool::scope(jobs);
}

/// C = A @ B on raw row-major slices, into a caller-owned (reused) buffer.
/// The engine's allocation-free forward path.
pub fn matmul_into_slices(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    for v in c.iter_mut() {
        *v = 0.0;
    }
    matmul_into(a, b, c, m, k, n);
}

// ---------------------------------------------------------------------------
// In-place / accumulating variants (optimizer + fused-engine hot paths)
// ---------------------------------------------------------------------------

/// t *= s in place.
pub fn scale_in_place(a: &mut Tensor, s: f32) {
    for v in a.data_mut() {
        *v *= s;
    }
}

/// a -= b (in place).
pub fn sub_into(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.dims(), b.dims(), "sub_into shape mismatch");
    for (x, &y) in a.data_mut().iter_mut().zip(b.data()) {
        *x -= y;
    }
}

/// v = mu * v + g (the momentum recurrence, in place).
pub fn decay_axpy(v: &mut Tensor, mu: f32, g: &Tensor) {
    assert_eq!(v.dims(), g.dims(), "decay_axpy shape mismatch");
    for (vv, &gv) in v.data_mut().iter_mut().zip(g.data()) {
        *vv = mu * *vv + gv;
    }
}

/// `scale_rows` into a caller-owned buffer (no allocation).
pub fn scale_rows_into(a: &Tensor, coef: &[f32], out: &mut Tensor) {
    let (m, n) = (a.dims()[0], a.dims()[1]);
    assert_eq!(coef.len(), m);
    assert_eq!(out.dims(), a.dims(), "scale_rows_into shape mismatch");
    let src = a.data();
    let dst = out.data_mut();
    for i in 0..m {
        let c = coef[i];
        for (d, &s) in dst[i * n..(i + 1) * n].iter_mut().zip(&src[i * n..(i + 1) * n]) {
            *d = c * s;
        }
    }
}

/// C += A^T diag(coef) B on raw slices (coef `None` = identity), row-band
/// parallel over the k output rows on the persistent worker pool. This is
/// the paper-§6 rescale-recompute collapsed into a single kernel: the row
/// rescale `diag(coef)·B` never materializes. Per-band inner loops come
/// from [`kernels::active`].
pub fn matmul_tn_coef_acc_slices(
    a: &[f32],
    b: &[f32],
    coef: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if let Some(cf) = coef {
        assert_eq!(cf.len(), m, "coef length must equal contraction dim");
    }
    let kern = kernels::active();
    if k * n <= PAR_THRESHOLD || k == 1 {
        kern.tn_band(a, b, coef, c, 0, k, k, n, m);
        return;
    }
    let bands = threadpool::bands().min(k);
    let rows_per = k.div_ceil(bands);
    let jobs: Vec<threadpool::ScopedJob> = c
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(bi, chunk)| {
            let k0 = bi * rows_per;
            let k1 = k0 + chunk.len() / n;
            Box::new(move || kern.tn_band(a, b, coef, chunk, k0, k1, k, n, m))
                as threadpool::ScopedJob
        })
        .collect();
    threadpool::scope(jobs);
}

/// C += A^T @ B for rank-2 tensors (accumulating, no transpose temp).
pub fn matmul_tn_acc(a: &Tensor, b: &Tensor, c: &mut Tensor) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (m2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(m, m2, "matmul_tn_acc contraction dim: {m} vs {m2}");
    assert_eq!(c.dims(), &[k, n], "matmul_tn_acc output shape");
    matmul_tn_coef_acc_slices(a.data(), b.data(), None, c.data_mut(), m, k, n);
}

/// C += A^T diag(coef) B for rank-2 tensors — the fused §6 kernel.
pub fn matmul_tn_coef_acc(a: &Tensor, b: &Tensor, coef: &[f32], c: &mut Tensor) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (m2, n) = (b.dims()[0], b.dims()[1]);
    assert_eq!(m, m2, "matmul_tn_coef_acc contraction dim: {m} vs {m2}");
    assert_eq!(c.dims(), &[k, n], "matmul_tn_coef_acc output shape");
    matmul_tn_coef_acc_slices(a.data(), b.data(), Some(coef), c.data_mut(), m, k, n);
}

/// Append the constant-1 bias column (paper §2's augmented h).
pub fn augment(h: &Tensor) -> Tensor {
    let (m, n) = (h.dims()[0], h.dims()[1]);
    let mut out = Tensor::zeros(vec![m, n + 1]);
    for i in 0..m {
        out.data_mut()[i * (n + 1)..i * (n + 1) + n].copy_from_slice(h.row(i));
        out.data_mut()[i * (n + 1) + n] = 1.0;
    }
    out
}

/// Drop the last column (inverse of `augment` for gradient flow).
pub fn drop_last_col(h: &Tensor) -> Tensor {
    let (m, n1) = (h.dims()[0], h.dims()[1]);
    let n = n1 - 1;
    let mut out = Tensor::zeros(vec![m, n]);
    for i in 0..m {
        out.data_mut()[i * n..(i + 1) * n].copy_from_slice(&h.row(i)[..n]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::util::prop;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.dims()[0], a.dims()[1]);
        let n = b.dims()[1];
        let mut c = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f64;
                for kk in 0..k {
                    acc += (a.at2(i, kk) as f64) * (b.at2(kk, j) as f64);
                }
                c.set2(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_prop() {
        prop::check(25, |g| {
            let (m, k, n) = (
                g.usize_in(1..40),
                g.usize_in(1..40),
                g.usize_in(1..40),
            );
            let mut rng = Rng::new(g.case);
            let a = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![k, n], &mut rng);
            prop::assert_all_close(matmul(&a, &b).data(), naive_matmul(&a, &b).data(), 1e-3)
        });
    }

    #[test]
    fn matmul_parallel_path() {
        // Big enough to cross PAR_THRESHOLD.
        let mut rng = Rng::new(0);
        let a = Tensor::randn(vec![200, 120], &mut rng);
        let b = Tensor::randn(vec![120, 150], &mut rng);
        let got = matmul(&a, &b);
        let want = naive_matmul(&a, &b);
        prop::assert_all_close(got.data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn matmul_tn_and_nt() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(vec![12, 7], &mut rng);
        let b = Tensor::randn(vec![12, 9], &mut rng);
        let want = naive_matmul(&transpose(&a), &b);
        prop::assert_all_close(matmul_tn(&a, &b).data(), want.data(), 1e-3).unwrap();

        let c = Tensor::randn(vec![5, 7], &mut rng);
        let d = Tensor::randn(vec![9, 7], &mut rng);
        let want = naive_matmul(&c, &transpose(&d));
        prop::assert_all_close(matmul_nt(&c, &d).data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(vec![33, 71], &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn row_sq_norms_basic() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 2.0, 0.0, -3.0, 4.0]);
        assert_eq!(row_sq_norms(&t), vec![9.0, 25.0]);
    }

    #[test]
    fn augment_and_drop() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let a = augment(&t);
        assert_eq!(a.dims(), &[2, 3]);
        assert_eq!(a.row(0), &[1.0, 2.0, 1.0]);
        assert_eq!(drop_last_col(&a), t);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let t = Tensor::randn(vec![4, 9], &mut rng);
        let s = softmax_rows(&t);
        for i in 0..4 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_stable_at_large_logits() {
        let t = Tensor::new(vec![1, 3], vec![1000.0, 1000.0, 1000.0]);
        let ls = log_softmax_rows(&t);
        for &v in ls.data() {
            assert!((v - (-(3f32).ln())).abs() < 1e-4);
        }
    }

    #[test]
    fn activations_match_finite_difference() {
        prop::check(40, |g| {
            let act = *g.choose(&[
                Activation::Relu,
                Activation::Tanh,
                Activation::Gelu,
                Activation::Sigmoid,
                Activation::Identity,
            ]);
            let z = g.f32_in(-3.0..3.0);
            if matches!(act, Activation::Relu) && z.abs() < 1e-2 {
                return Ok(()); // kink
            }
            let h = 1e-3f32;
            let fd = (act.apply(z + h) - act.apply(z - h)) / (2.0 * h);
            prop::assert_close(act.grad(z) as f64, fd as f64, 5e-2)
        });
    }

    #[test]
    fn activation_parse_roundtrip() {
        for name in ["relu", "tanh", "gelu", "sigmoid", "identity"] {
            assert_eq!(Activation::parse(name).unwrap().name(), name);
        }
        assert!(Activation::parse("swish").is_none());
    }

    #[test]
    fn scale_rows_matches_manual() {
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let s = scale_rows(&t, &[2.0, 0.5]);
        assert_eq!(s.data(), &[2.0, 4.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_in_place() {
        let mut a = Tensor::ones(vec![3]);
        let b = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]);
        axpy(&mut a, -0.5, &b);
        assert_eq!(a.data(), &[0.5, 0.0, -0.5]);
    }

    #[test]
    fn row_argmax_ties_first() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 3.0, 3.0, 5.0, 2.0, 1.0]);
        assert_eq!(row_argmax(&t), vec![1, 0]);
    }

    #[test]
    fn in_place_elementwise_variants() {
        let mut a = Tensor::new(vec![3], vec![2.0, 4.0, 6.0]);
        scale_in_place(&mut a, 0.5);
        assert_eq!(a.data(), &[1.0, 2.0, 3.0]);
        sub_into(&mut a, &Tensor::ones(vec![3]));
        assert_eq!(a.data(), &[0.0, 1.0, 2.0]);
        let mut v = Tensor::new(vec![3], vec![1.0, 1.0, 1.0]);
        decay_axpy(&mut v, 0.5, &a);
        assert_eq!(v.data(), &[0.5, 1.5, 2.5]);
    }

    #[test]
    fn scale_rows_into_matches_scale_rows() {
        let mut rng = Rng::new(6);
        let t = Tensor::randn(vec![5, 7], &mut rng);
        let coef = [0.0, 1.0, -2.0, 0.5, 3.0];
        let mut out = Tensor::zeros(vec![5, 7]);
        scale_rows_into(&t, &coef, &mut out);
        assert_eq!(out, scale_rows(&t, &coef));
    }

    #[test]
    fn matmul_tn_acc_matches_matmul_tn() {
        prop::check(20, |g| {
            let (m, k, n) = (g.usize_in(1..30), g.usize_in(1..30), g.usize_in(1..30));
            let mut rng = Rng::new(g.case + 77);
            let a = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![m, n], &mut rng);
            let mut c = Tensor::randn(vec![k, n], &mut rng);
            let want = add(&c, &matmul_tn(&a, &b));
            matmul_tn_acc(&a, &b, &mut c);
            prop::assert_all_close(c.data(), want.data(), 1e-3)
        });
    }

    #[test]
    fn matmul_tn_coef_acc_matches_scale_rows_then_matmul() {
        prop::check(20, |g| {
            let (m, k, n) = (g.usize_in(1..25), g.usize_in(1..25), g.usize_in(1..25));
            let mut rng = Rng::new(g.case + 99);
            let a = Tensor::randn(vec![m, k], &mut rng);
            let b = Tensor::randn(vec![m, n], &mut rng);
            let coef: Vec<f32> = (0..m).map(|_| rng.next_f32() * 2.0 - 0.5).collect();
            let mut c = Tensor::zeros(vec![k, n]);
            matmul_tn_coef_acc(&a, &b, &coef, &mut c);
            let want = matmul_tn(&a, &scale_rows(&b, &coef));
            prop::assert_all_close(c.data(), want.data(), 1e-3)
        });
    }

    #[test]
    fn matmul_tn_coef_acc_parallel_band_path() {
        // large enough that k*n crosses PAR_THRESHOLD
        let mut rng = Rng::new(12);
        let a = Tensor::randn(vec![64, 150], &mut rng);
        let b = Tensor::randn(vec![64, 130], &mut rng);
        let coef: Vec<f32> = (0..64).map(|_| rng.next_f32()).collect();
        let mut c = Tensor::zeros(vec![150, 130]);
        matmul_tn_coef_acc(&a, &b, &coef, &mut c);
        let want = matmul_tn(&a, &scale_rows(&b, &coef));
        prop::assert_all_close(c.data(), want.data(), 1e-3).unwrap();
    }

    #[test]
    fn matmul_into_slices_matches_matmul() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(vec![40, 30], &mut rng);
        let b = Tensor::randn(vec![30, 20], &mut rng);
        let mut c = vec![9.9f32; 40 * 20]; // stale contents must be overwritten
        matmul_into_slices(a.data(), b.data(), &mut c, 40, 30, 20);
        prop::assert_all_close(&c, matmul(&a, &b).data(), 1e-3).unwrap();
    }
}
