//! im2col / col2im kernels for the convolutional layer subsystem.
//!
//! Layout conventions (shared with `nn::layers::conv`):
//!
//! * per-example feature maps are **channel-last** (NHWC): a flat
//!   `[h * w * c]` slice with `x[(y*w + x_)*c + ch]`. A conv's matmul
//!   output `[L, c_out]` (L = out_h·out_w positions, row-major over
//!   (oy, ox)) is then *already* the next layer's NHWC input — no
//!   transpose between layers.
//! * the unfolded patch matrix `U_j` is `[L, K+1]` with
//!   `K = k*k*in_ch`, patch column order `(ky, kx, ch)`, and a constant
//!   `1.0` in the last column — the bias folded exactly like the dense
//!   path's `Haug` augmentation, so a conv weight is `[K+1, c_out]` with
//!   the bias as its last row.
//!
//! Both kernels fan out across example bands on the persistent worker
//! pool ([`threadpool::scope`]); each example's rows/outputs are disjoint,
//! so any banding is bitwise identical to the serial loop.

use crate::util::threadpool;

/// Static geometry of one stride-1, valid-padding k×k convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub in_h: usize,
    pub in_w: usize,
    pub in_ch: usize,
    pub k: usize,
}

impl ConvGeom {
    pub fn out_h(&self) -> usize {
        self.in_h + 1 - self.k
    }

    pub fn out_w(&self) -> usize {
        self.in_w + 1 - self.k
    }

    /// Number of output positions L.
    pub fn positions(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Patch length K (without the folded bias column).
    pub fn patch_len(&self) -> usize {
        self.k * self.k * self.in_ch
    }

    pub fn in_len(&self) -> usize {
        self.in_h * self.in_w * self.in_ch
    }
}

/// Below this many unfolded elements per call the im2col loop stays
/// single-threaded.
const IM2COL_PAR_THRESHOLD: usize = 1 << 15;

/// Unfold one NHWC example into its `[L, K+1]` patch matrix (bias column
/// of ones included).
fn im2col_example(g: &ConvGeom, x: &[f32], u: &mut [f32]) {
    let (out_h, out_w, k, c) = (g.out_h(), g.out_w(), g.k, g.in_ch);
    let kp1 = g.patch_len() + 1;
    let row_stride = g.in_w * c;
    debug_assert_eq!(x.len(), g.in_len());
    debug_assert_eq!(u.len(), g.positions() * kp1);
    for oy in 0..out_h {
        for ox in 0..out_w {
            let urow = &mut u[(oy * out_w + ox) * kp1..(oy * out_w + ox + 1) * kp1];
            for ky in 0..k {
                let src = &x[(oy + ky) * row_stride + ox * c..][..k * c];
                urow[ky * k * c..(ky + 1) * k * c].copy_from_slice(src);
            }
            urow[kp1 - 1] = 1.0;
        }
    }
}

/// Batched im2col: `x` is `[m, in_len]` NHWC, `u` is `[m, L*(K+1)]`,
/// band-parallel over examples on the pooled workers.
pub fn im2col(g: &ConvGeom, x: &[f32], u: &mut [f32], m: usize) {
    let per_u = g.positions() * (g.patch_len() + 1);
    let per_x = g.in_len();
    debug_assert_eq!(x.len(), m * per_x);
    debug_assert_eq!(u.len(), m * per_u);
    if m * per_u <= IM2COL_PAR_THRESHOLD || m == 1 {
        for j in 0..m {
            im2col_example(g, &x[j * per_x..(j + 1) * per_x], &mut u[j * per_u..(j + 1) * per_u]);
        }
        return;
    }
    let bands = threadpool::bands().min(m);
    let rows_per = m.div_ceil(bands);
    let jobs: Vec<threadpool::ScopedJob> = u
        .chunks_mut(rows_per * per_u)
        .enumerate()
        .map(|(bi, chunk)| {
            let j0 = bi * rows_per;
            Box::new(move || {
                for (dj, uc) in chunk.chunks_mut(per_u).enumerate() {
                    let j = j0 + dj;
                    im2col_example(g, &x[j * per_x..(j + 1) * per_x], uc);
                }
            }) as threadpool::ScopedJob
        })
        .collect();
    threadpool::scope(jobs);
}

/// Fold one example's patch-gradient matrix `du` (`[L, K]`, the bias
/// column already dropped by the caller) back onto the NHWC input
/// gradient `dx` (`[in_len]`, overwritten): every patch position
/// scatter-adds into the pixels it covered. The inverse of
/// [`im2col_example`]'s gather.
pub fn col2im_example(g: &ConvGeom, du: &[f32], dx: &mut [f32]) {
    let (out_h, out_w, k, c) = (g.out_h(), g.out_w(), g.k, g.in_ch);
    let kc = g.patch_len();
    let row_stride = g.in_w * c;
    debug_assert_eq!(du.len(), g.positions() * kc);
    debug_assert_eq!(dx.len(), g.in_len());
    for v in dx.iter_mut() {
        *v = 0.0;
    }
    for oy in 0..out_h {
        for ox in 0..out_w {
            let drow = &du[(oy * out_w + ox) * kc..(oy * out_w + ox + 1) * kc];
            for ky in 0..k {
                let dst = &mut dx[(oy + ky) * row_stride + ox * c..][..k * c];
                for (d, &s) in dst.iter_mut().zip(&drow[ky * k * c..(ky + 1) * k * c]) {
                    *d += s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};

    fn geom() -> ConvGeom {
        ConvGeom {
            in_h: 5,
            in_w: 4,
            in_ch: 2,
            k: 3,
        }
    }

    #[test]
    fn geometry() {
        let g = geom();
        assert_eq!((g.out_h(), g.out_w()), (3, 2));
        assert_eq!(g.positions(), 6);
        assert_eq!(g.patch_len(), 18);
        assert_eq!(g.in_len(), 40);
    }

    #[test]
    fn im2col_gathers_patches_with_bias_column() {
        let g = geom();
        let x: Vec<f32> = (0..g.in_len()).map(|v| v as f32).collect();
        let kp1 = g.patch_len() + 1;
        let mut u = vec![0f32; g.positions() * kp1];
        im2col_example(&g, &x, &mut u);
        // patch at (oy=1, ox=1): rows 1..4, cols 1..4, both channels
        let l = g.out_w() + 1;
        let urow = &u[l * kp1..(l + 1) * kp1];
        for ky in 0..3 {
            for kx in 0..3 {
                for ch in 0..2 {
                    let want = ((1 + ky) * 4 * 2 + (1 + kx) * 2 + ch) as f32;
                    assert_eq!(urow[(ky * 3 + kx) * 2 + ch], want, "ky{ky} kx{kx} ch{ch}");
                }
            }
        }
        assert_eq!(urow[kp1 - 1], 1.0);
    }

    #[test]
    fn batched_im2col_parallel_matches_serial_bitwise() {
        // large enough to cross the parallel threshold, ragged band sizes
        let g = ConvGeom {
            in_h: 12,
            in_w: 12,
            in_ch: 3,
            k: 3,
        };
        let m = 37;
        let mut rng = Rng::new(5);
        let x = Tensor::randn(vec![m, g.in_len()], &mut rng);
        let per_u = g.positions() * (g.patch_len() + 1);
        assert!(m * per_u > IM2COL_PAR_THRESHOLD);
        let mut par = vec![0f32; m * per_u];
        im2col(&g, x.data(), &mut par, m);
        let mut ser = vec![0f32; m * per_u];
        for j in 0..m {
            im2col_example(
                &g,
                &x.data()[j * g.in_len()..(j + 1) * g.in_len()],
                &mut ser[j * per_u..(j + 1) * per_u],
            );
        }
        assert_eq!(par, ser, "banded im2col diverged from serial");
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), u> == <x, col2im(u)> for random x, u — the defining
        // property of the gather/scatter pair (bias column excluded).
        let g = geom();
        let mut rng = Rng::new(9);
        let x = Tensor::randn(vec![g.in_len()], &mut rng);
        let du = Tensor::randn(vec![g.positions() * g.patch_len()], &mut rng);
        let kp1 = g.patch_len() + 1;
        let mut u = vec![0f32; g.positions() * kp1];
        im2col_example(&g, x.data(), &mut u);
        let lhs: f64 = (0..g.positions())
            .flat_map(|l| (0..g.patch_len()).map(move |p| (l, p)))
            .map(|(l, p)| u[l * kp1 + p] as f64 * du.data()[l * g.patch_len() + p] as f64)
            .sum();
        let mut dx = vec![0f32; g.in_len()];
        col2im_example(&g, du.data(), &mut dx);
        let rhs: f64 = x
            .data()
            .iter()
            .zip(&dx)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn k1_conv_is_identity_unfold() {
        let g = ConvGeom {
            in_h: 2,
            in_w: 2,
            in_ch: 3,
            k: 1,
        };
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut u = vec![0f32; g.positions() * 4];
        im2col_example(&g, &x, &mut u);
        for l in 0..4 {
            assert_eq!(&u[l * 4..l * 4 + 3], &x[l * 3..(l + 1) * 3]);
            assert_eq!(u[l * 4 + 3], 1.0);
        }
    }
}
