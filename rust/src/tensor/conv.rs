//! Patch gather/scatter kernels for the convolutional layer subsystem:
//! the implicit-GEMM inner gather plus the materialized im2col/col2im
//! baseline built on top of it.
//!
//! Layout conventions (shared with `nn::layers::conv`):
//!
//! * per-example feature maps are **channel-last** (NHWC): a flat
//!   `[h * w * c]` slice with `x[(y*w + x_)*c + ch]`. A conv's matmul
//!   output `[L, c_out]` (L = out_h·out_w positions, row-major over
//!   (oy, ox)) is then *already* the next layer's NHWC input — no
//!   transpose between layers.
//! * the unfolded patch matrix `U_j` is `[L, K+1]` with
//!   `K = k*k*in_ch`, patch column order `(ky, kx, ch)`, and a constant
//!   `1.0` in the last column — the bias folded exactly like the dense
//!   path's `Haug` augmentation, so a conv weight is `[K+1, c_out]` with
//!   the bias as its last row. Zero-padded positions contribute `0.0`
//!   patch entries (the bias column stays `1.0`).
//!
//! [`gather_patch`] materializes ONE `[K+1]` patch row at a time — the
//! implicit-GEMM kernels in `nn::layers::conv2d` call it inside their
//! matmul loops so the full `[m, L·(K+1)]` unfold never exists.
//! [`im2col`] (the baseline, kept for the e10 bench comparison and as a
//! test oracle) is just that gather looped over all positions; both
//! therefore produce bitwise-identical patch values. Batched im2col fans
//! out across example bands on the persistent worker pool
//! ([`threadpool::scope`]); each example's rows/outputs are disjoint, so
//! any banding is bitwise identical to the serial loop.

use crate::util::threadpool;

/// Static geometry of one k×k convolution with stride `stride` and
/// symmetric zero padding `pad` (stride 1 / pad 0 = the original valid
/// convolution; see [`ConvGeom::unit`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Input height.
    pub in_h: usize,
    /// Input width.
    pub in_w: usize,
    /// Input channel count.
    pub in_ch: usize,
    /// Square kernel side length.
    pub k: usize,
    /// Stride (same in both spatial dims).
    pub stride: usize,
    /// Symmetric zero padding (same in both spatial dims).
    pub pad: usize,
}

impl ConvGeom {
    /// Stride-1, valid-padding geometry (the PR-3 constructor).
    pub fn unit(in_h: usize, in_w: usize, in_ch: usize, k: usize) -> ConvGeom {
        ConvGeom {
            in_h,
            in_w,
            in_ch,
            k,
            stride: 1,
            pad: 0,
        }
    }

    /// Output height.
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Output width.
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Number of output positions L.
    pub fn positions(&self) -> usize {
        self.out_h() * self.out_w()
    }

    /// Patch length K (without the folded bias column).
    pub fn patch_len(&self) -> usize {
        self.k * self.k * self.in_ch
    }

    /// Flattened input length `in_h * in_w * in_ch`.
    pub fn in_len(&self) -> usize {
        self.in_h * self.in_w * self.in_ch
    }
}

/// Below this many unfolded elements per call the im2col loop stays
/// single-threaded.
const IM2COL_PAR_THRESHOLD: usize = 1 << 15;

/// Gather the `li`-th patch row of one NHWC example into `urow`
/// (`[K+1]`, bias `1.0` in the last slot) — the implicit-GEMM inner
/// gather. Out-of-bounds (padded) positions read as `0.0`. Produces
/// exactly the values an im2col unfold would have materialized for this
/// row, bitwise.
pub fn gather_patch(g: &ConvGeom, x: &[f32], li: usize, urow: &mut [f32]) {
    let (out_w, k, c) = (g.out_w(), g.k, g.in_ch);
    let kp1 = g.patch_len() + 1;
    debug_assert_eq!(x.len(), g.in_len());
    debug_assert_eq!(urow.len(), kp1);
    let row_stride = g.in_w * c;
    let (oy, ox) = (li / out_w, li % out_w);
    if g.pad == 0 {
        // fast path: every (ky, kx) is in bounds, rows copy contiguously
        let (y0, x0) = (oy * g.stride, ox * g.stride);
        for ky in 0..k {
            let src = &x[(y0 + ky) * row_stride + x0 * c..][..k * c];
            urow[ky * k * c..(ky + 1) * k * c].copy_from_slice(src);
        }
    } else {
        let y0 = (oy * g.stride) as isize - g.pad as isize;
        let x0 = (ox * g.stride) as isize - g.pad as isize;
        for ky in 0..k {
            let dst = &mut urow[ky * k * c..(ky + 1) * k * c];
            let yy = y0 + ky as isize;
            if yy < 0 || yy >= g.in_h as isize {
                dst.fill(0.0);
                continue;
            }
            let kx_lo = (-x0).clamp(0, k as isize) as usize;
            let kx_hi = (g.in_w as isize - x0).clamp(0, k as isize) as usize;
            dst[..kx_lo * c].fill(0.0);
            dst[kx_hi * c..].fill(0.0);
            if kx_lo < kx_hi {
                let src0 = yy as usize * row_stride + (x0 + kx_lo as isize) as usize * c;
                dst[kx_lo * c..kx_hi * c]
                    .copy_from_slice(&x[src0..src0 + (kx_hi - kx_lo) * c]);
            }
        }
    }
    urow[kp1 - 1] = 1.0;
}

/// Scatter-add the `li`-th patch-gradient row `du` (`[K]`, the bias
/// column already dropped by the caller) onto the NHWC input gradient
/// `dx` — the col2im inner step, and the adjoint of [`gather_patch`].
/// Contributions that fell on padding are discarded.
pub fn scatter_patch_add(g: &ConvGeom, du: &[f32], li: usize, dx: &mut [f32]) {
    let (out_w, k, c) = (g.out_w(), g.k, g.in_ch);
    debug_assert_eq!(du.len(), g.patch_len());
    debug_assert_eq!(dx.len(), g.in_len());
    let row_stride = g.in_w * c;
    let (oy, ox) = (li / out_w, li % out_w);
    if g.pad == 0 {
        let (y0, x0) = (oy * g.stride, ox * g.stride);
        for ky in 0..k {
            let dst = &mut dx[(y0 + ky) * row_stride + x0 * c..][..k * c];
            for (d, &s) in dst.iter_mut().zip(&du[ky * k * c..(ky + 1) * k * c]) {
                *d += s;
            }
        }
    } else {
        let y0 = (oy * g.stride) as isize - g.pad as isize;
        let x0 = (ox * g.stride) as isize - g.pad as isize;
        for ky in 0..k {
            let yy = y0 + ky as isize;
            if yy < 0 || yy >= g.in_h as isize {
                continue;
            }
            let kx_lo = (-x0).clamp(0, k as isize) as usize;
            let kx_hi = (g.in_w as isize - x0).clamp(0, k as isize) as usize;
            if kx_lo >= kx_hi {
                continue;
            }
            let dst0 = yy as usize * row_stride + (x0 + kx_lo as isize) as usize * c;
            let srow = &du[ky * k * c + kx_lo * c..ky * k * c + kx_hi * c];
            for (d, &s) in dx[dst0..dst0 + (kx_hi - kx_lo) * c].iter_mut().zip(srow) {
                *d += s;
            }
        }
    }
}

/// Unfold one NHWC example into its `[L, K+1]` patch matrix (bias column
/// of ones included) — [`gather_patch`] looped over every position.
fn im2col_example(g: &ConvGeom, x: &[f32], u: &mut [f32]) {
    let kp1 = g.patch_len() + 1;
    debug_assert_eq!(u.len(), g.positions() * kp1);
    for (li, urow) in u.chunks_mut(kp1).enumerate() {
        gather_patch(g, x, li, urow);
    }
}

/// Batched im2col: `x` is `[m, in_len]` NHWC, `u` is `[m, L*(K+1)]`,
/// band-parallel over examples on the pooled workers.
pub fn im2col(g: &ConvGeom, x: &[f32], u: &mut [f32], m: usize) {
    let per_u = g.positions() * (g.patch_len() + 1);
    let per_x = g.in_len();
    debug_assert_eq!(x.len(), m * per_x);
    debug_assert_eq!(u.len(), m * per_u);
    if m * per_u <= IM2COL_PAR_THRESHOLD || m == 1 {
        for j in 0..m {
            im2col_example(g, &x[j * per_x..(j + 1) * per_x], &mut u[j * per_u..(j + 1) * per_u]);
        }
        return;
    }
    let bands = threadpool::bands().min(m);
    let rows_per = m.div_ceil(bands);
    let jobs: Vec<threadpool::ScopedJob> = u
        .chunks_mut(rows_per * per_u)
        .enumerate()
        .map(|(bi, chunk)| {
            let j0 = bi * rows_per;
            Box::new(move || {
                for (dj, uc) in chunk.chunks_mut(per_u).enumerate() {
                    let j = j0 + dj;
                    im2col_example(g, &x[j * per_x..(j + 1) * per_x], uc);
                }
            }) as threadpool::ScopedJob
        })
        .collect();
    threadpool::scope(jobs);
}

/// Fold one example's patch-gradient matrix `du` (`[L, K]`, the bias
/// column already dropped by the caller) back onto the NHWC input
/// gradient `dx` (`[in_len]`, overwritten): every patch position
/// scatter-adds into the pixels it covered. The inverse of
/// [`im2col_example`]'s gather.
pub fn col2im_example(g: &ConvGeom, du: &[f32], dx: &mut [f32]) {
    let kc = g.patch_len();
    debug_assert_eq!(du.len(), g.positions() * kc);
    debug_assert_eq!(dx.len(), g.in_len());
    for v in dx.iter_mut() {
        *v = 0.0;
    }
    for (li, drow) in du.chunks(kc).enumerate() {
        scatter_patch_add(g, drow, li, dx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};

    fn geom() -> ConvGeom {
        ConvGeom::unit(5, 4, 2, 3)
    }

    #[test]
    fn geometry() {
        let g = geom();
        assert_eq!((g.out_h(), g.out_w()), (3, 2));
        assert_eq!(g.positions(), 6);
        assert_eq!(g.patch_len(), 18);
        assert_eq!(g.in_len(), 40);
    }

    #[test]
    fn strided_padded_geometry() {
        // 5x5, k3, stride 2, pad 1: out = (5 + 2 - 3)/2 + 1 = 3
        let g = ConvGeom {
            in_h: 5,
            in_w: 5,
            in_ch: 1,
            k: 3,
            stride: 2,
            pad: 1,
        };
        assert_eq!((g.out_h(), g.out_w()), (3, 3));
        // 'same' conv: 12x12, k3, stride 1, pad 1 keeps the spatial dims
        let same = ConvGeom {
            in_h: 12,
            in_w: 12,
            in_ch: 3,
            k: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!((same.out_h(), same.out_w()), (12, 12));
        // stride with flooring: 6x6, k3, stride 2 -> (6-3)/2 + 1 = 2
        let fl = ConvGeom {
            in_h: 6,
            in_w: 6,
            in_ch: 1,
            k: 3,
            stride: 2,
            pad: 0,
        };
        assert_eq!((fl.out_h(), fl.out_w()), (2, 2));
    }

    #[test]
    fn im2col_gathers_patches_with_bias_column() {
        let g = geom();
        let x: Vec<f32> = (0..g.in_len()).map(|v| v as f32).collect();
        let kp1 = g.patch_len() + 1;
        let mut u = vec![0f32; g.positions() * kp1];
        im2col_example(&g, &x, &mut u);
        // patch at (oy=1, ox=1): rows 1..4, cols 1..4, both channels
        let l = g.out_w() + 1;
        let urow = &u[l * kp1..(l + 1) * kp1];
        for ky in 0..3 {
            for kx in 0..3 {
                for ch in 0..2 {
                    let want = ((1 + ky) * 4 * 2 + (1 + kx) * 2 + ch) as f32;
                    assert_eq!(urow[(ky * 3 + kx) * 2 + ch], want, "ky{ky} kx{kx} ch{ch}");
                }
            }
        }
        assert_eq!(urow[kp1 - 1], 1.0);
    }

    /// Reference gather: index arithmetic written the obvious way,
    /// sharing no code with [`gather_patch`].
    fn reference_patch(g: &ConvGeom, x: &[f32], li: usize) -> Vec<f32> {
        let (out_w, k, c) = (g.out_w(), g.k, g.in_ch);
        let (oy, ox) = (li / out_w, li % out_w);
        let mut row = vec![0f32; g.patch_len() + 1];
        for ky in 0..k {
            for kx in 0..k {
                for ch in 0..c {
                    let yy = (oy * g.stride + ky) as isize - g.pad as isize;
                    let xx = (ox * g.stride + kx) as isize - g.pad as isize;
                    row[(ky * k + kx) * c + ch] = if yy >= 0
                        && xx >= 0
                        && (yy as usize) < g.in_h
                        && (xx as usize) < g.in_w
                    {
                        x[(yy as usize * g.in_w + xx as usize) * c + ch]
                    } else {
                        0.0
                    };
                }
            }
        }
        row[g.patch_len()] = 1.0;
        row
    }

    #[test]
    fn strided_padded_gather_matches_reference() {
        let mut rng = Rng::new(3);
        for g in [
            ConvGeom {
                in_h: 7,
                in_w: 6,
                in_ch: 2,
                k: 3,
                stride: 2,
                pad: 1,
            },
            ConvGeom {
                in_h: 5,
                in_w: 5,
                in_ch: 3,
                k: 3,
                stride: 1,
                pad: 2,
            },
            ConvGeom {
                in_h: 8,
                in_w: 8,
                in_ch: 1,
                k: 2,
                stride: 2,
                pad: 0,
            },
        ] {
            let x = Tensor::randn(vec![g.in_len()], &mut rng);
            let mut urow = vec![0f32; g.patch_len() + 1];
            for li in 0..g.positions() {
                gather_patch(&g, x.data(), li, &mut urow);
                assert_eq!(
                    urow,
                    reference_patch(&g, x.data(), li),
                    "geom {g:?} position {li}"
                );
            }
        }
    }

    #[test]
    fn batched_im2col_parallel_matches_serial_bitwise() {
        // large enough to cross the parallel threshold, ragged band sizes
        let g = ConvGeom::unit(12, 12, 3, 3);
        let m = 37;
        let mut rng = Rng::new(5);
        let x = Tensor::randn(vec![m, g.in_len()], &mut rng);
        let per_u = g.positions() * (g.patch_len() + 1);
        assert!(m * per_u > IM2COL_PAR_THRESHOLD);
        let mut par = vec![0f32; m * per_u];
        im2col(&g, x.data(), &mut par, m);
        let mut ser = vec![0f32; m * per_u];
        for j in 0..m {
            im2col_example(
                &g,
                &x.data()[j * g.in_len()..(j + 1) * g.in_len()],
                &mut ser[j * per_u..(j + 1) * per_u],
            );
        }
        assert_eq!(par, ser, "banded im2col diverged from serial");
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), u> == <x, col2im(u)> for random x, u — the defining
        // property of the gather/scatter pair (bias column excluded) —
        // including strided/padded geometries.
        let mut rng = Rng::new(9);
        for g in [
            geom(),
            ConvGeom {
                in_h: 6,
                in_w: 7,
                in_ch: 2,
                k: 3,
                stride: 2,
                pad: 1,
            },
        ] {
            let x = Tensor::randn(vec![g.in_len()], &mut rng);
            let du = Tensor::randn(vec![g.positions() * g.patch_len()], &mut rng);
            let kp1 = g.patch_len() + 1;
            let mut u = vec![0f32; g.positions() * kp1];
            im2col_example(&g, x.data(), &mut u);
            let lhs: f64 = (0..g.positions())
                .flat_map(|l| (0..g.patch_len()).map(move |p| (l, p)))
                .map(|(l, p)| u[l * kp1 + p] as f64 * du.data()[l * g.patch_len() + p] as f64)
                .sum();
            let mut dx = vec![0f32; g.in_len()];
            col2im_example(&g, du.data(), &mut dx);
            let rhs: f64 = x
                .data()
                .iter()
                .zip(&dx)
                .map(|(&a, &b)| a as f64 * b as f64)
                .sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "{g:?}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn k1_conv_is_identity_unfold() {
        let g = ConvGeom::unit(2, 2, 3, 1);
        let x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut u = vec![0f32; g.positions() * 4];
        im2col_example(&g, &x, &mut u);
        for l in 0..4 {
            assert_eq!(&u[l * 4..l * 4 + 3], &x[l * 3..(l + 1) * 3]);
            assert_eq!(u[l * 4 + 3], 1.0);
        }
    }
}
