//! Differential privacy for the §6 extension: per-example clipping is the
//! DP-SGD primitive; combined with Gaussian noise it yields (ε, δ)-DP
//! guarantees tracked by an RDP accountant.
//!
//! (System map: `docs/architecture.md`.)

pub mod accountant;
pub mod calibrate;

pub use accountant::RdpAccountant;
pub use calibrate::clip_from_quantile;
