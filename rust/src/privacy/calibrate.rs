//! Clip-bound calibration from observed per-example norms.
//!
//! The standard DP-SGD heuristic: set C to a quantile (often the median)
//! of the per-example gradient norms observed on public/warmup data — a
//! direct consumer of the trick's output.

use crate::util::stats::percentile_sorted;

/// Choose a clip bound as the `q`-th percentile (0-100) of observed norms.
/// Returns a small positive floor if no finite norms were observed.
pub fn clip_from_quantile(norms: &[f32], q: f64) -> f32 {
    let mut v: Vec<f64> = norms
        .iter()
        .filter(|n| n.is_finite() && **n >= 0.0)
        .map(|&n| n as f64)
        .collect();
    if v.is_empty() {
        return 1e-3;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile_sorted(&v, q.clamp(0.0, 100.0)) as f32).max(1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_of_known_set() {
        let norms = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        assert!((clip_from_quantile(&norms, 50.0) - 3.0).abs() < 1e-6);
        assert!((clip_from_quantile(&norms, 100.0) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn ignores_nonfinite() {
        let norms = [f32::NAN, 2.0, f32::INFINITY, 4.0];
        let c = clip_from_quantile(&norms, 50.0);
        assert!((c - 3.0).abs() < 1e-6);
    }

    #[test]
    fn empty_gives_floor() {
        assert!(clip_from_quantile(&[], 50.0) > 0.0);
        assert!(clip_from_quantile(&[f32::NAN], 50.0) > 0.0);
    }
}
