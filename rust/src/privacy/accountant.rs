//! RDP accountant for the subsampled Gaussian mechanism (Mironov 2017;
//! Mironov, Talwar & Zhang 2019 for the subsampled bound).
//!
//! `step_clipped` adds `sigma * C` Gaussian noise to a sum of
//! norm-C-clipped per-example gradients, with each example included via
//! Poisson-like subsampling at rate `q = m / N`. Per step, the RDP of
//! order α is bounded (for integer α, the standard moments-accountant
//! bound) by
//!
//! ```text
//! ε_RDP(α) = (1/(α-1)) · ln Σ_{k=0..α} C(α,k) (1-q)^(α-k) q^k
//!                        · exp(k(k-1) / (2σ²))
//! ```
//!
//! RDP composes additively across steps; conversion to (ε, δ)-DP uses
//! `ε = min_α [ ε_RDP(α) + ln(1/δ)/(α-1) ]`.

/// Orders α over which the accountant minimizes.
fn default_orders() -> Vec<f64> {
    let mut o: Vec<f64> = (2..64).map(|a| a as f64).collect();
    o.extend([64.0, 80.0, 96.0, 128.0, 256.0, 512.0]);
    o
}

/// Tracks cumulative RDP across training steps.
#[derive(Debug, Clone)]
pub struct RdpAccountant {
    /// subsampling rate q = batch / dataset.
    pub q: f64,
    /// noise multiplier σ (noise std = σ·C on the SUM of clipped grads).
    pub sigma: f64,
    orders: Vec<f64>,
    /// accumulated ε_RDP per order.
    rdp: Vec<f64>,
    /// Steps accounted so far.
    pub steps: u64,
}

impl RdpAccountant {
    /// Accountant for subsampling rate `q` and noise multiplier `sigma`.
    pub fn new(q: f64, sigma: f64) -> RdpAccountant {
        assert!((0.0..=1.0).contains(&q), "subsampling rate q in [0,1]");
        assert!(sigma > 0.0, "sigma must be positive");
        let orders = default_orders();
        RdpAccountant {
            q,
            sigma,
            rdp: vec![0.0; orders.len()],
            orders,
            steps: 0,
        }
    }

    /// RDP of one subsampled-Gaussian step at integer order α.
    fn step_rdp(&self, alpha: f64) -> f64 {
        let (q, sigma) = (self.q, self.sigma);
        if q >= 1.0 {
            // no subsampling amplification: ε_RDP(α) = α / (2σ²)
            return alpha / (2.0 * sigma * sigma);
        }
        // integer-α binomial bound, computed in log space
        let a = alpha as usize;
        let mut log_terms = Vec::with_capacity(a + 1);
        for k in 0..=a {
            let log_binom = ln_binomial(a, k);
            let lt = log_binom
                + (a - k) as f64 * (1.0 - q).ln()
                + k as f64 * q.ln()
                + (k * (k.saturating_sub(1))) as f64 / (2.0 * sigma * sigma);
            log_terms.push(lt);
        }
        let m = log_terms.iter().cloned().fold(f64::MIN, f64::max);
        let sum: f64 = log_terms.iter().map(|&t| (t - m).exp()).sum();
        (m + sum.ln()) / (alpha - 1.0)
    }

    /// Record `n` composed steps.
    pub fn observe_steps(&mut self, n: u64) {
        let per_step: Vec<f64> = self.orders.iter().map(|&a| self.step_rdp(a)).collect();
        for (acc, ps) in self.rdp.iter_mut().zip(&per_step) {
            *acc += ps * n as f64;
        }
        self.steps += n;
    }

    /// Current (ε, δ)-DP guarantee.
    pub fn epsilon(&self, delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        self.orders
            .iter()
            .zip(&self.rdp)
            .map(|(&a, &r)| r + (1.0 / delta).ln() / (a - 1.0))
            .fold(f64::INFINITY, f64::min)
    }
}

/// ln C(n, k) via lgamma.
fn ln_binomial(n: usize, k: usize) -> f64 {
    ln_gamma((n + 1) as f64) - ln_gamma((k + 1) as f64) - ln_gamma((n - k + 1) as f64)
}

/// Lanczos ln Γ(x) (x > 0), double precision adequate for accounting.
fn ln_gamma(x: f64) -> f64 {
    // Lanczos g=7, n=9 coefficients
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..10usize {
            let fact: f64 = (1..n).map(|k| k as f64).product();
            assert!(
                (ln_gamma(n as f64) - fact.ln()).abs() < 1e-9,
                "lnΓ({n})"
            );
        }
    }

    #[test]
    fn epsilon_grows_with_steps() {
        let mut acc = RdpAccountant::new(0.01, 1.1);
        acc.observe_steps(100);
        let e1 = acc.epsilon(1e-5);
        acc.observe_steps(900);
        let e2 = acc.epsilon(1e-5);
        assert!(e2 > e1);
        assert!(e1 > 0.0);
    }

    #[test]
    fn more_noise_less_epsilon() {
        let eps = |sigma: f64| {
            let mut a = RdpAccountant::new(0.02, sigma);
            a.observe_steps(1000);
            a.epsilon(1e-5)
        };
        assert!(eps(2.0) < eps(1.0));
        assert!(eps(4.0) < eps(2.0));
    }

    #[test]
    fn smaller_sampling_rate_less_epsilon() {
        let eps = |q: f64| {
            let mut a = RdpAccountant::new(q, 1.0);
            a.observe_steps(1000);
            a.epsilon(1e-5)
        };
        assert!(eps(0.001) < eps(0.01));
        assert!(eps(0.01) < eps(0.1));
    }

    #[test]
    fn ballpark_matches_published_dpsgd_numbers() {
        // Abadi et al.-era setting: q=0.01, sigma=1.1, T=10000, δ=1e-5.
        // The tight moments accountant reports ε≈2-4; the plain
        // integer-order RDP bound used here is somewhat looser — accept
        // the published ballpark plus that known slack (ε in (1, 8)).
        let mut a = RdpAccountant::new(0.01, 1.1);
        a.observe_steps(10_000);
        let e = a.epsilon(1e-5);
        assert!(e > 1.0 && e < 8.0, "ε = {e}");
    }

    #[test]
    fn no_subsampling_closed_form() {
        // q=1: ε_RDP(α) = α T / (2σ²); conversion picks the best α.
        let mut a = RdpAccountant::new(1.0, 10.0);
        a.observe_steps(1);
        let e = a.epsilon(1e-5);
        // optimal α for one step: ε = α/(2σ²) + ln(1/δ)/(α-1), minimized
        let manual: f64 = (2..512)
            .map(|al| al as f64 / 200.0 + (1e5f64).ln() / (al as f64 - 1.0))
            .fold(f64::INFINITY, f64::min);
        assert!((e - manual).abs() < 0.05, "{e} vs {manual}");
    }

    #[test]
    #[should_panic]
    fn zero_sigma_rejected() {
        RdpAccountant::new(0.01, 0.0);
    }
}
