//! `pegrad serve` — the concurrent multi-run training/monitoring
//! daemon (system map: `docs/architecture.md`, operations guide:
//! `docs/serving.md`).
//!
//! One process schedules N scenario runs at a time over the ONE shared
//! scoped-dispatch threadpool ([`crate::util::threadpool`], whose
//! workers never block on latches — the property that makes concurrent
//! callers safe). Each run gets its own arena: its own
//! [`crate::coordinator::Trainer`] (engine + workspace), its own run
//! directory and stream writers, its
//! own driver thread ([`crate::coordinator::trainer::RunSession`]).
//! The only shared mutable state is the pool's job queue and the
//! process-global trace counters.
//!
//! Work arrives two ways, composable:
//! * a **fleet spec** — a TOML file listing scenario configs
//!   ([`Fleet::from_file`], schema in `docs/serving.md`);
//! * a **spool directory** — any `*.toml` config dropped into it while
//!   the daemon runs is picked up and scheduled.
//!
//! The daemon appends a `serve.jsonl` status stream (tag
//! [`SERVE_TAG`], schema v1 in `docs/streams.md`) with per-run state,
//! steps/sec, queue depth and pool utilization — consumable live by
//! `pegrad monitor --follow` and schema-checked by
//! `scripts/validate_stream`. Graceful shutdown
//! ([`ServeHandle::shutdown`], or `--max-seconds`) checkpoints every
//! active run at a clean step boundary so each resumes bitwise
//! (noise-free runs; proven in `tests/serve.rs`). A run that fails —
//! or outright panics — is contained to its driver thread and reported
//! in the stream without stalling its siblings.
//!
//! Throughput + tail latency at N = 1/2/4 concurrent runs are measured
//! by `benches/e12_service.rs` and gated in CI by `scripts/perf_gate`.

pub mod fleet;
pub mod server;
pub mod status;

pub use fleet::{Fleet, RunSpec, ServeOptions};
pub use server::{RunReport, RunState, ServeHandle, ServeReport, Server};
pub use status::SERVE_TAG;
