//! The serve scheduler: driver threads, run lifecycle, status stream.
//!
//! One driver thread per *active* run (bounded by
//! [`ServeOptions::max_concurrent`]); each driver builds its
//! [`Trainer`] on-thread (the trainer is deliberately not `Send` — the
//! engine arena never crosses threads) and advances it one step at a
//! time via the session API, so the scheduler can interleave launches,
//! spool pickups, status emission and shutdown between any two steps
//! of any run. Compute still funnels through the ONE shared scoped
//! threadpool; driver threads only orchestrate.
//!
//! Lifecycle per run: `pending → running → completed | interrupted |
//! failed`. `interrupted` means graceful shutdown landed first: the run
//! executed its in-flight step WITHOUT drawing the next selection
//! lookahead, checkpointed synchronously, and will resume bitwise
//! (noise-free configs; `tests/serve.rs` proves it). `failed` covers
//! both `Err` returns and panics — a panicking run is contained to its
//! driver thread by `catch_unwind` and reported in `serve.jsonl`
//! without stalling siblings.
//!
//! Shutdown has three triggers, all funneling into one shared flag:
//! [`ServeHandle::shutdown`] (any thread), the `--max-seconds`
//! deadline, and fleet drain (no spool). See `docs/serving.md`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{RunSummary, Trainer};
use crate::serve::fleet::{Fleet, RunSpec, ServeOptions};
use crate::serve::status::{render_status, RunStatus, ServeSnapshot};
use crate::trace::StreamWriter;
use crate::util::Timer;

/// At most this many recent per-step latencies are kept per run (a
/// ring, so long runs report their tail, not their warmup).
const STEP_SAMPLE_CAP: usize = 4096;

/// Scheduler poll cadence while runs are active (the step loop itself
/// never waits on this — drivers run freely between polls).
const POLL: Duration = Duration::from_millis(5);

/// Spool rescan cadence.
const SPOOL_SCAN: Duration = Duration::from_millis(200);

/// Run lifecycle state, as reported in `serve.jsonl` and
/// [`RunReport`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunState {
    /// Accepted, waiting for a driver slot.
    Pending,
    /// Stepping on a driver thread.
    Running,
    /// Ran to its configured end step.
    Completed,
    /// Stopped early by graceful shutdown; a resume checkpoint was
    /// written at a clean step boundary.
    Interrupted,
    /// Returned an error or panicked; siblings were unaffected.
    Failed,
}

impl RunState {
    /// The lowercase label used in `serve.jsonl` (`"state"` field).
    pub fn label(&self) -> &'static str {
        match self {
            RunState::Pending => "pending",
            RunState::Running => "running",
            RunState::Completed => "completed",
            RunState::Interrupted => "interrupted",
            RunState::Failed => "failed",
        }
    }

    /// True once the run can no longer change state.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            RunState::Completed | RunState::Interrupted | RunState::Failed
        )
    }
}

/// What one scheduled run came to: returned in
/// [`ServeReport::runs`], in completion order.
#[derive(Debug)]
pub struct RunReport {
    /// Final (possibly uniquified) run name.
    pub name: String,
    /// Terminal state (`Completed`, `Interrupted` or `Failed`).
    pub state: RunState,
    /// Global step the trainer reached.
    pub steps_done: usize,
    /// Step the run was configured to stop at.
    pub steps_total: usize,
    /// The run directory (metrics, streams, checkpoints), when the
    /// trainer got far enough to create one.
    pub run_dir: Option<PathBuf>,
    /// Shutdown checkpoint, for `Interrupted` runs.
    pub checkpoint: Option<PathBuf>,
    /// Error / panic message, for `Failed` runs.
    pub error: Option<String>,
    /// The trainer's own summary, for runs that finished a session.
    pub summary: Option<RunSummary>,
    /// Recent per-step wall latencies in ms (ring of the last
    /// `STEP_SAMPLE_CAP`; the service bench derives p50/p99 here).
    pub step_ms: Vec<f64>,
}

impl RunReport {
    fn failed(name: &str, steps_total: usize, error: String) -> RunReport {
        RunReport {
            name: name.to_string(),
            state: RunState::Failed,
            steps_done: 0,
            steps_total,
            run_dir: None,
            checkpoint: None,
            error: Some(error),
            summary: None,
            step_ms: Vec::new(),
        }
    }
}

/// Everything `Server::run` came to, for the CLI / bench / tests.
#[derive(Debug)]
pub struct ServeReport {
    /// Terminal reports, one per started run, in completion order.
    pub runs: Vec<RunReport>,
    /// Names of runs still queued when shutdown landed (never started,
    /// nothing to resume — rerun them).
    pub skipped: Vec<String>,
    /// Spooled files that failed to load, with the reason (the daemon
    /// keeps serving; a bad drop must not take down good runs).
    pub spool_rejected: Vec<(PathBuf, String)>,
    /// Where `serve.jsonl` landed.
    pub status_path: PathBuf,
    /// Status lines handed to the writer (backpressure drops excluded).
    pub status_lines: u64,
    /// Total serve wall time.
    pub elapsed_secs: f64,
}

impl ServeReport {
    /// How many runs ended in `state`.
    pub fn count(&self, state: RunState) -> usize {
        self.runs.iter().filter(|r| r.state == state).count()
    }

    /// Runs that reached their configured end step.
    pub fn completed(&self) -> usize {
        self.count(RunState::Completed)
    }

    /// Runs checkpointed early by graceful shutdown.
    pub fn interrupted(&self) -> usize {
        self.count(RunState::Interrupted)
    }

    /// Runs that errored or panicked.
    pub fn failed(&self) -> usize {
        self.count(RunState::Failed)
    }
}

/// Cloneable remote control for a running [`Server`]: any thread may
/// request graceful shutdown.
#[derive(Debug, Clone)]
pub struct ServeHandle {
    stop: Arc<AtomicBool>,
}

impl ServeHandle {
    /// Request graceful shutdown: every active run executes its
    /// in-flight step, checkpoints, and reports `interrupted`; queued
    /// runs are skipped; the server returns once all drivers join.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// True once shutdown has been requested (by any trigger).
    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }
}

/// Messages drivers post to the scheduler thread.
enum Event {
    /// The trainer constructed and the session opened.
    Started { name: String, steps_total: usize },
    /// One step executed.
    Progress { name: String, step: usize },
    /// The driver is done (boxed: reports carry curves).
    Finished(Box<RunReport>),
}

/// Scheduler-side view of one launched run.
struct Tracker {
    name: String,
    state: RunState,
    step: usize,
    steps_total: usize,
    rate: f64,
    /// `step` at the previous status emit (rate window).
    last_step: usize,
    error: Option<String>,
    checkpoint: Option<PathBuf>,
}

/// The serve daemon: owns the queue, launches driver threads, emits
/// `serve.jsonl`. Construct with [`Server::new`], feed it with
/// [`Server::enqueue_fleet`] / a spool directory, then block on
/// [`Server::run`].
pub struct Server {
    opts: ServeOptions,
    queue: VecDeque<RunSpec>,
    /// Every name ever accepted (uniquification set).
    names: HashSet<String>,
    /// Spool paths fully resolved (accepted or finally rejected).
    spool_seen: HashSet<PathBuf>,
    /// Paths that failed to parse on the last scan, with the (size,
    /// mtime) snapshot taken at that failure: a `.toml` caught mid-write
    /// parses again on later scans and is only REJECTED once its
    /// metadata has been stable across a full rescan interval —
    /// write-then-rename drops still land instantly, plain writes settle
    /// within one extra scan instead of being permanently torn.
    spool_pending: HashMap<PathBuf, (u64, Option<std::time::SystemTime>)>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Validate the options and build an idle server.
    pub fn new(opts: ServeOptions) -> Result<Server> {
        opts.validate()?;
        Ok(Server {
            opts,
            queue: VecDeque::new(),
            names: HashSet::new(),
            spool_seen: HashSet::new(),
            spool_pending: HashMap::new(),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The session directory: `{out_dir}/{name}` (holds `serve.jsonl`).
    pub fn session_dir(&self) -> PathBuf {
        Path::new(&self.opts.out_dir).join(&self.opts.name)
    }

    /// A shutdown control usable from other threads.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle {
            stop: Arc::clone(&self.stop),
        }
    }

    /// Accept one run. Collisions with any previously accepted name are
    /// renamed `{name}-r2`, `{name}-r3`, … (the run directory must be
    /// unique); the final name is returned and also written into the
    /// spec's `run_name` so the run directory matches `serve.jsonl`.
    pub fn enqueue(&mut self, mut spec: RunSpec) -> String {
        let mut name = spec.name.clone();
        let mut k = 2;
        while !self.names.insert(name.clone()) {
            name = format!("{}-r{k}", spec.name);
            k += 1;
        }
        spec.name = name.clone();
        spec.config.run_name = name.clone();
        // runs of one serve session share the session's out_dir parent
        spec.config.out_dir = self.opts.out_dir.clone();
        self.queue.push_back(spec);
        name
    }

    /// Accept a whole fleet, in fleet-file order.
    pub fn enqueue_fleet(&mut self, fleet: Fleet) {
        for spec in fleet.specs {
            self.enqueue(spec);
        }
    }

    /// Serve until drained (fleet mode), or until shutdown (spool mode /
    /// [`ServeHandle::shutdown`] / the `max_seconds` deadline). Blocks;
    /// returns once every driver thread has joined.
    pub fn run(&mut self) -> Result<ServeReport> {
        let dir = self.session_dir();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow!("creating serve dir {}: {e}", dir.display()))?;
        let status_path = dir.join("serve.jsonl");
        let writer = StreamWriter::create(&status_path, self.opts.buffer)?;
        log::info!(
            "serve '{}': {} queued, max_concurrent={}, status -> {}",
            self.opts.name,
            self.queue.len(),
            self.opts.max_concurrent,
            status_path.display()
        );

        // Pool utilization comes from the process-global PR-7 trace
        // counters; keep them hot for the whole session (re-asserted
        // per emit — a finishing traced run flips them off).
        let trace_was = crate::trace::enabled();
        crate::trace::set_enabled(true);
        let workers = crate::util::threadpool::bands();
        let mut pool_prev = crate::trace::counters().pool_busy_nanos;

        let total = Timer::start();
        let interval = Duration::from_millis(self.opts.status_every_ms);
        let (tx, rx) = mpsc::channel::<Event>();
        let mut trackers: Vec<Tracker> = Vec::new();
        let mut reports: Vec<RunReport> = Vec::new();
        let mut spool_rejected: Vec<(PathBuf, String)> = Vec::new();
        let mut active: Vec<(String, std::thread::JoinHandle<()>)> = Vec::new();
        let mut seq = 0u64;

        // seq-0 snapshot before anything launches: a monitor attached
        // at startup sees the full pending fleet immediately
        self.scan_spool(&mut spool_rejected);
        emit_status(
            &writer,
            &mut seq,
            total.millis(),
            total.secs(),
            0.0,
            workers,
            &mut trackers,
            &self.queue,
            0,
        );
        let mut last_emit = Instant::now();
        let mut last_scan = Instant::now();

        loop {
            if let Some(max_s) = self.opts.max_seconds {
                if total.secs() >= max_s && !self.stop.load(Ordering::Relaxed) {
                    log::info!("serve: max_seconds={max_s} reached, shutting down");
                    self.stop.store(true, Ordering::Relaxed);
                }
            }
            let stopping = self.stop.load(Ordering::Relaxed);

            if !stopping && last_scan.elapsed() >= SPOOL_SCAN {
                self.scan_spool(&mut spool_rejected);
                last_scan = Instant::now();
            }

            while !stopping && active.len() < self.opts.max_concurrent {
                let Some(spec) = self.queue.pop_front() else {
                    break;
                };
                let name = spec.name.clone();
                trackers.push(Tracker {
                    name: name.clone(),
                    state: RunState::Running,
                    step: 0,
                    steps_total: spec.config.steps,
                    rate: 0.0,
                    last_step: 0,
                    error: None,
                    checkpoint: None,
                });
                let stop = Arc::clone(&self.stop);
                let txc = tx.clone();
                log::info!("serve: starting run '{name}'");
                let handle = std::thread::Builder::new()
                    .name(format!("pegrad-run-{name}"))
                    .spawn(move || drive(spec, stop, txc))
                    .map_err(|e| anyhow!("spawning driver thread: {e}"))?;
                active.push((name, handle));
            }

            drain_events(&rx, &mut trackers, &mut reports);

            let mut still = Vec::new();
            for (name, handle) in active.drain(..) {
                if !handle.is_finished() {
                    still.push((name, handle));
                } else if handle.join().is_err() {
                    // unreachable by construction (drive() never panics:
                    // the run body is under catch_unwind) — but a run
                    // must never vanish silently, so synthesize a report
                    if let Some(t) = tracker_mut(&mut trackers, &name) {
                        if !t.state.is_terminal() {
                            t.state = RunState::Failed;
                            t.error = Some("driver thread aborted".into());
                            reports.push(RunReport::failed(
                                &name,
                                t.steps_total,
                                "driver thread aborted".into(),
                            ));
                        }
                    }
                }
            }
            active = still;

            if last_emit.elapsed() >= interval {
                crate::trace::set_enabled(true);
                let dt = last_emit.elapsed().as_secs_f64();
                let util = pool_utilization(&mut pool_prev, workers, dt);
                emit_status(
                    &writer,
                    &mut seq,
                    total.millis(),
                    dt,
                    util,
                    workers,
                    &mut trackers,
                    &self.queue,
                    active.len(),
                );
                last_emit = Instant::now();
            }

            if active.is_empty()
                && (stopping || (self.queue.is_empty() && self.opts.spool.is_none()))
            {
                break;
            }
            std::thread::sleep(POLL);
        }

        // Drivers have all joined; pick up any Finished events posted
        // between the last drain and the join, then emit the final line.
        drain_events(&rx, &mut trackers, &mut reports);
        let dt = last_emit.elapsed().as_secs_f64();
        let util = pool_utilization(&mut pool_prev, workers, dt);
        emit_status(
            &writer,
            &mut seq,
            total.millis(),
            dt,
            util,
            workers,
            &mut trackers,
            &self.queue,
            0,
        );
        let status_lines = seq;
        let dropped = writer.finish();
        if dropped > 0 {
            log::warn!("serve: {dropped} status line(s) dropped under backpressure");
        }
        crate::trace::set_enabled(trace_was);

        let skipped: Vec<String> =
            self.queue.drain(..).map(|s| s.name).collect();
        let report = ServeReport {
            runs: reports,
            skipped,
            spool_rejected,
            status_path,
            status_lines,
            elapsed_secs: total.secs(),
        };
        log::info!(
            "serve '{}' done in {:.2}s: {} completed, {} interrupted, {} failed, {} skipped",
            self.opts.name,
            report.elapsed_secs,
            report.completed(),
            report.interrupted(),
            report.failed(),
            report.skipped.len()
        );
        Ok(report)
    }

    /// Ingest new `*.toml` drops from the spool directory. A file that
    /// fails to parse is retried on later scans until its size/mtime
    /// have been stable across one rescan interval (a writer may still
    /// be mid-write); only a SETTLED file that still fails is finally
    /// rejected. Rejections are recorded, never fatal.
    fn scan_spool(&mut self, rejected: &mut Vec<(PathBuf, String)>) {
        let Some(spool) = self.opts.spool.clone() else {
            return;
        };
        let entries = match std::fs::read_dir(&spool) {
            Ok(e) => e,
            Err(e) => {
                log::warn!("serve: cannot read spool {}: {e}", spool.display());
                return;
            }
        };
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "toml"))
            .filter(|p| !self.spool_seen.contains(p))
            .collect();
        paths.sort();
        let overrides = self.opts.overrides.clone();
        for path in paths {
            match Fleet::load_spooled(&path, &overrides) {
                Ok(spec) => {
                    self.spool_seen.insert(path.clone());
                    self.spool_pending.remove(&path);
                    let name = self.enqueue(spec);
                    log::info!(
                        "serve: spooled {} as run '{name}'",
                        path.display()
                    );
                }
                Err(e) => {
                    let snap = std::fs::metadata(&path)
                        .ok()
                        .map(|md| (md.len(), md.modified().ok()));
                    let settled = match (&snap, self.spool_pending.get(&path)) {
                        // unchanged since the last failed scan: no writer
                        // is making progress — the file is really invalid
                        (Some(now), Some(prev)) => now == prev,
                        // vanished mid-scan: nothing left to retry
                        (None, _) => true,
                        // first failure: give the writer one interval
                        (Some(_), None) => false,
                    };
                    if settled {
                        self.spool_seen.insert(path.clone());
                        self.spool_pending.remove(&path);
                        log::warn!("serve: rejecting spooled {}: {e:#}", path.display());
                        rejected.push((path, format!("{e:#}")));
                    } else if let Some(s) = snap {
                        log::debug!(
                            "serve: spooled {} unparseable, waiting for it to settle: {e:#}",
                            path.display()
                        );
                        self.spool_pending.insert(path, s);
                    }
                }
            }
        }
    }
}

fn tracker_mut<'a>(trackers: &'a mut [Tracker], name: &str) -> Option<&'a mut Tracker> {
    trackers.iter_mut().find(|t| t.name == name)
}

fn drain_events(
    rx: &mpsc::Receiver<Event>,
    trackers: &mut [Tracker],
    reports: &mut Vec<RunReport>,
) {
    while let Ok(ev) = rx.try_recv() {
        match ev {
            Event::Started {
                name, steps_total, ..
            } => {
                if let Some(t) = tracker_mut(trackers, &name) {
                    t.steps_total = steps_total;
                }
            }
            Event::Progress { name, step } => {
                if let Some(t) = tracker_mut(trackers, &name) {
                    t.step = step;
                }
            }
            Event::Finished(r) => {
                if let Some(t) = tracker_mut(trackers, &r.name) {
                    t.state = r.state;
                    t.step = t.step.max(r.steps_done);
                    t.rate = 0.0;
                    t.error = r.error.clone();
                    t.checkpoint = r.checkpoint.clone();
                }
                log::info!(
                    "serve: run '{}' {} at step {}{}",
                    r.name,
                    r.state.label(),
                    r.steps_done,
                    r.error.as_deref().map(|e| format!(": {e}")).unwrap_or_default()
                );
                reports.push(*r);
            }
        }
    }
}

/// Diff the global pool-busy counter into a utilization fraction for
/// the last `dt` seconds.
fn pool_utilization(prev: &mut u64, workers: usize, dt: f64) -> f64 {
    let cur = crate::trace::counters().pool_busy_nanos;
    let busy = cur.saturating_sub(*prev) as f64;
    *prev = cur;
    if dt <= 0.0 || workers == 0 {
        return 0.0;
    }
    (busy / (dt * 1e9 * workers as f64)).clamp(0.0, 1.0)
}

#[allow(clippy::too_many_arguments)]
fn emit_status(
    writer: &StreamWriter,
    seq: &mut u64,
    elapsed_ms: f64,
    dt: f64,
    pool_utilization: f64,
    pool_workers: usize,
    trackers: &mut [Tracker],
    queue: &VecDeque<RunSpec>,
    active: usize,
) {
    let mut rows: Vec<RunStatus> = Vec::with_capacity(trackers.len() + queue.len());
    for t in trackers.iter_mut() {
        if t.state == RunState::Running && dt > 0.0 {
            t.rate = (t.step.saturating_sub(t.last_step)) as f64 / dt;
        }
        t.last_step = t.step;
        rows.push(RunStatus {
            run: t.name.clone(),
            state: t.state.label(),
            step: t.step,
            steps_total: t.steps_total,
            steps_per_sec: if t.state == RunState::Running { t.rate } else { 0.0 },
            error: t.error.clone(),
            checkpoint: t
                .checkpoint
                .as_ref()
                .map(|p| p.display().to_string()),
        });
    }
    for spec in queue {
        rows.push(RunStatus {
            run: spec.name.clone(),
            state: RunState::Pending.label(),
            step: 0,
            steps_total: spec.config.steps,
            steps_per_sec: 0.0,
            error: None,
            checkpoint: None,
        });
    }
    let snap = ServeSnapshot {
        seq: *seq,
        elapsed_ms,
        queue_depth: queue.len(),
        active,
        pool_workers,
        pool_utilization,
    };
    writer.enqueue(render_status(&snap, &rows).to_string());
    *seq += 1;
}

/// Driver-thread entry: everything that can fail or panic happens
/// under `catch_unwind`, and exactly one `Finished` event is posted.
fn drive(spec: RunSpec, stop: Arc<AtomicBool>, tx: mpsc::Sender<Event>) {
    let name = spec.name.clone();
    let steps_total = spec.config.steps;
    let outcome = catch_unwind(AssertUnwindSafe(|| run_one(spec, &stop, &tx)));
    let report = match outcome {
        Ok(Ok(report)) => report,
        Ok(Err(e)) => RunReport::failed(&name, steps_total, format!("{e:#}")),
        Err(payload) => RunReport::failed(
            &name,
            steps_total,
            format!("panic: {}", panic_text(payload.as_ref())),
        ),
    };
    let _ = tx.send(Event::Finished(Box::new(report)));
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("opaque panic payload")
}

/// The per-run body: build the trainer ON this thread, open a session,
/// step until done or stopped, checkpoint on stop, close the session.
fn run_one(
    spec: RunSpec,
    stop: &AtomicBool,
    tx: &mpsc::Sender<Event>,
) -> Result<RunReport> {
    let RunSpec {
        name,
        config,
        panic_after,
    } = spec;
    let mut tr = Trainer::new(config)?;
    let run_dir = tr.metrics.dir().to_path_buf();
    let mut session = tr.begin_session()?;
    let steps_total = session.end_step();
    let _ = tx.send(Event::Started {
        name: name.clone(),
        steps_total,
    });

    let mut ring: Vec<f64> = Vec::new();
    let mut ring_at = 0usize;
    loop {
        if let Some(after) = panic_after {
            if session.steps_executed() >= after {
                panic!("chaos: injected panic in run '{name}' after {after} steps");
            }
        }
        let stop_now = stop.load(Ordering::Relaxed);
        let before = session.steps_executed();
        let t = Timer::start();
        let more = tr.step_session(&mut session, stop_now)?;
        if session.steps_executed() > before {
            let ms = t.millis();
            if ring.len() < STEP_SAMPLE_CAP {
                ring.push(ms);
            } else {
                ring[ring_at] = ms;
                ring_at = (ring_at + 1) % STEP_SAMPLE_CAP;
            }
            let _ = tx.send(Event::Progress {
                name: name.clone(),
                step: tr.current_step(),
            });
        }
        if !more {
            break;
        }
    }

    let interrupted = session.stopped();
    // The stopped step drew no selection lookahead, so the RNG sits
    // exactly where a fresh run would start the next step: this
    // checkpoint resumes bitwise. Synchronous on purpose — shutdown
    // must not race process exit.
    let checkpoint = if interrupted {
        Some(tr.save_checkpoint()?)
    } else {
        None
    };
    let steps_done = tr.current_step();
    let summary = tr.finish_session(session)?;
    Ok(RunReport {
        name,
        state: if interrupted {
            RunState::Interrupted
        } else {
            RunState::Completed
        },
        steps_done,
        steps_total,
        run_dir: Some(run_dir),
        checkpoint,
        error: None,
        summary: Some(summary),
        step_ms: ring,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn tiny_cfg(name: &str, out: &Path, steps: usize) -> Config {
        let mut cfg = Config::from_toml(
            r#"
            mode = "rust_pegrad"
            steps = 4
            eval_every = 0
            checkpoint_every = 0
            [data]
            kind = "synth"
            n = 64
            [model]
            dims = [8, 12, 4]
            m = 8
            "#,
        )
        .unwrap();
        cfg.run_name = name.to_string();
        cfg.out_dir = out.display().to_string();
        cfg.steps = steps;
        cfg
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pegrad_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn names_are_uniquified() {
        let d = tmpdir("uniq");
        let opts = ServeOptions {
            out_dir: d.display().to_string(),
            ..ServeOptions::default()
        };
        let mut server = Server::new(opts).unwrap();
        let a = server.enqueue(RunSpec::new(tiny_cfg("x", &d, 2)));
        let b = server.enqueue(RunSpec::new(tiny_cfg("x", &d, 2)));
        let c = server.enqueue(RunSpec::new(tiny_cfg("x", &d, 2)));
        assert_eq!(a, "x");
        assert_eq!(b, "x-r2");
        assert_eq!(c, "x-r3");
    }

    #[test]
    fn fleet_drains_and_completes() {
        let d = tmpdir("drain");
        let opts = ServeOptions {
            name: "sess".into(),
            out_dir: d.display().to_string(),
            max_concurrent: 2,
            status_every_ms: 10,
            ..ServeOptions::default()
        };
        let mut server = Server::new(opts).unwrap();
        server.enqueue(RunSpec::new(tiny_cfg("a", &d, 3)));
        server.enqueue(RunSpec::new(tiny_cfg("b", &d, 3)));
        let report = server.run().unwrap();
        assert_eq!(report.completed(), 2);
        assert_eq!(report.failed(), 0);
        assert!(report.status_lines >= 1);
        assert!(report.status_path.exists());
        for r in &report.runs {
            assert_eq!(r.steps_done, 3);
            assert_eq!(r.summary.as_ref().unwrap().steps, 3);
            assert!(!r.step_ms.is_empty());
        }
    }

    #[test]
    fn rejects_zero_concurrency() {
        let opts = ServeOptions {
            max_concurrent: 0,
            ..ServeOptions::default()
        };
        assert!(Server::new(opts).is_err());
    }
}
