//! The `serve.jsonl` status stream: schema v1 rendering.
//!
//! One line per status interval (plus one final line at exit), tagged
//! `"serve": "pegrad.serve"` so consumers can route it alongside the
//! trace/telemetry/saliency streams. The full schema contract lives in
//! `docs/streams.md`; `scripts/validate_stream` enforces it in CI.
//!
//! Rendering is pure: the server passes a snapshot of its trackers and
//! this module builds the [`Json`] line. Keys are emitted through
//! [`Json::obj`] (BTreeMap-backed), so key order is deterministic and
//! lines are byte-diffable across runs.

use crate::util::json::Json;

/// Tag value carried by every `serve.jsonl` line (key `"serve"`),
/// mirroring [`crate::trace::TRACE_TAG`] et al. for the other streams.
pub const SERVE_TAG: &str = "pegrad.serve";

/// `serve.jsonl` schema version emitted in the `"v"` field.
pub const SCHEMA_VERSION: u64 = 1;

/// Snapshot of one scheduled run, as the status renderer sees it.
///
/// The server owns the mutable tracker; this is the flattened view it
/// hands to [`render_status`] each interval.
#[derive(Debug, Clone)]
pub struct RunStatus {
    /// Run name (unique within the serve session; doubles as the run
    /// directory name).
    pub run: String,
    /// Lifecycle state label: `pending`, `running`, `completed`,
    /// `interrupted` or `failed`.
    pub state: &'static str,
    /// Global step the trainer has reached (0 until the run starts).
    pub step: usize,
    /// Step this run will stop at (config `steps`, plus any restored
    /// offset).
    pub steps_total: usize,
    /// Steps/sec over the last status interval (0 when idle).
    pub steps_per_sec: f64,
    /// Error message, present only for `failed` runs.
    pub error: Option<String>,
    /// Shutdown checkpoint path, present only for `interrupted` runs.
    pub checkpoint: Option<String>,
}

/// Aggregate, non-per-run fields of one status line.
#[derive(Debug, Clone, Copy)]
pub struct ServeSnapshot {
    /// Monotone line sequence number, from 0.
    pub seq: u64,
    /// Milliseconds since the server started.
    pub elapsed_ms: f64,
    /// Runs accepted but not yet started.
    pub queue_depth: usize,
    /// Runs currently stepping on a driver thread.
    pub active: usize,
    /// Shared-threadpool worker count.
    pub pool_workers: usize,
    /// Fraction of worker capacity busy over the last interval, in
    /// `[0, 1]` (diffed from the PR-7 trace counters).
    pub pool_utilization: f64,
}

/// Build one `serve.jsonl` line (schema v1; see `docs/streams.md`).
pub fn render_status(snap: &ServeSnapshot, runs: &[RunStatus]) -> Json {
    let mut completed = 0usize;
    let mut interrupted = 0usize;
    let mut failed = 0usize;
    for r in runs {
        match r.state {
            "completed" => completed += 1,
            "interrupted" => interrupted += 1,
            "failed" => failed += 1,
            _ => {}
        }
    }
    let run_rows: Vec<Json> = runs.iter().map(render_run).collect();
    Json::obj(vec![
        ("v", Json::num(SCHEMA_VERSION as f64)),
        ("serve", Json::str(SERVE_TAG)),
        ("seq", Json::num(snap.seq as f64)),
        ("elapsed_ms", Json::num(snap.elapsed_ms)),
        ("queue_depth", Json::num(snap.queue_depth as f64)),
        ("active", Json::num(snap.active as f64)),
        ("completed", Json::num(completed as f64)),
        ("interrupted", Json::num(interrupted as f64)),
        ("failed", Json::num(failed as f64)),
        (
            "pool",
            Json::obj(vec![
                ("workers", Json::num(snap.pool_workers as f64)),
                ("utilization", Json::num(snap.pool_utilization)),
            ]),
        ),
        ("runs", Json::Arr(run_rows)),
    ])
}

fn render_run(r: &RunStatus) -> Json {
    let mut pairs = vec![
        ("run", Json::str(r.run.as_str())),
        ("state", Json::str(r.state)),
        ("step", Json::num(r.step as f64)),
        ("steps_total", Json::num(r.steps_total as f64)),
        ("steps_per_sec", Json::num(r.steps_per_sec)),
    ];
    if let Some(e) = &r.error {
        pairs.push(("error", Json::str(e.as_str())));
    }
    if let Some(c) = &r.checkpoint {
        pairs.push(("checkpoint", Json::str(c.as_str())));
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(state: &'static str) -> RunStatus {
        RunStatus {
            run: "r".into(),
            state,
            step: 3,
            steps_total: 10,
            steps_per_sec: 12.5,
            error: None,
            checkpoint: None,
        }
    }

    #[test]
    fn line_has_tag_version_and_counts() {
        let snap = ServeSnapshot {
            seq: 2,
            elapsed_ms: 40.0,
            queue_depth: 1,
            active: 1,
            pool_workers: 8,
            pool_utilization: 0.5,
        };
        let runs = vec![run("running"), run("completed"), run("failed")];
        let line = render_status(&snap, &runs);
        assert_eq!(line.get("serve").unwrap().as_str().unwrap(), SERVE_TAG);
        assert_eq!(line.get("v").unwrap().as_usize().unwrap(), 1);
        assert_eq!(line.get("seq").unwrap().as_usize().unwrap(), 2);
        assert_eq!(line.get("completed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(line.get("failed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(line.get("interrupted").unwrap().as_usize().unwrap(), 0);
        assert_eq!(
            line.get("pool").unwrap().get("workers").unwrap().as_usize(),
            Some(8)
        );
        assert_eq!(line.get("runs").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn error_and_checkpoint_are_optional() {
        let mut ok = run("running");
        ok.checkpoint = None;
        let row = render_run(&ok);
        assert!(row.get("error").is_none());
        assert!(row.get("checkpoint").is_none());

        let mut bad = run("failed");
        bad.error = Some("boom".into());
        let row = render_run(&bad);
        assert_eq!(row.get("error").unwrap().as_str().unwrap(), "boom");

        let mut stopped = run("interrupted");
        stopped.checkpoint = Some("runs/a/ckpt.pegd".into());
        let row = render_run(&stopped);
        assert!(row.get("checkpoint").unwrap().as_str().is_some());
    }

    #[test]
    fn lines_are_parseable_jsonl() {
        let snap = ServeSnapshot {
            seq: 0,
            elapsed_ms: 0.0,
            queue_depth: 0,
            active: 0,
            pool_workers: 4,
            pool_utilization: 0.0,
        };
        let text = render_status(&snap, &[]).to_string();
        assert!(!text.contains('\n'));
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("runs").unwrap().as_arr().unwrap().len(), 0);
    }
}
