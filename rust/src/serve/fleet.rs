//! Fleet specs: what the serve daemon is asked to run.
//!
//! A fleet spec is a small TOML file (parsed with the same
//! [`crate::config::parse`] subset as scenario configs) naming the
//! scenario configs to schedule plus the daemon's own options:
//!
//! ```toml
//! [serve]
//! name = "nightly"            # serve session name (serve.jsonl dir)
//! out_dir = "runs"            # parent of the session directory
//! max_concurrent = 2          # driver threads stepping at once
//! status_every_ms = 500       # serve.jsonl cadence
//! # spool = "spool"           # optional: watch this dir for configs
//! # max_seconds = 120.0       # optional: auto-shutdown deadline
//!
//! [fleet]
//! configs = ["digits_small.toml", "digits_conv.toml"]
//! ```
//!
//! Config paths are resolved **relative to the fleet file's directory**
//! so a spec can live next to the configs it names. Each run config is
//! loaded eagerly at spec-load time — a typo fails fast, before any
//! sibling run has started. The full schema contract lives in
//! `docs/serving.md`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::config::parse::parse_toml;
use crate::config::Config;

/// One scheduled run: a name (unique within the serve session; see
/// [`crate::serve::Server::enqueue`]) plus the fully-loaded scenario
/// config it will train with.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Run name; seeds the run-directory name and the `"run"` field of
    /// `serve.jsonl` rows. May be renamed (`-r2`, `-r3`, …) on enqueue
    /// if it collides with an earlier run.
    pub name: String,
    /// The scenario config (must be a rust-engine mode; serve drives
    /// many trainers concurrently and the PJRT path is single-client).
    pub config: Config,
    /// Chaos hook for the isolation tests: panic the driver thread
    /// after this many executed steps. Never set by fleet files.
    pub panic_after: Option<usize>,
}

impl RunSpec {
    /// A spec named after `config.run_name`.
    pub fn new(config: Config) -> RunSpec {
        RunSpec {
            name: config.run_name.clone(),
            config,
            panic_after: None,
        }
    }

    /// Builder: arm the chaos hook (tests only).
    pub fn with_panic_after(mut self, steps: usize) -> RunSpec {
        self.panic_after = Some(steps);
        self
    }

    /// Reject configs the serve scheduler cannot drive concurrently.
    pub fn validate(&self) -> Result<()> {
        self.config.validate()?;
        if !self.config.mode.is_rust_engine() {
            bail!(
                "run '{}': serve requires a rust-engine mode (got {:?}); \
                 artifact modes hold a single PJRT client and cannot run \
                 concurrently",
                self.name,
                self.config.mode
            );
        }
        Ok(())
    }
}

/// Daemon-level options, from the `[serve]` section and/or CLI flags
/// (flags win; see `pegrad serve --help`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Serve session name: `serve.jsonl` lands in
    /// `{out_dir}/{name}/serve.jsonl`.
    pub name: String,
    /// Parent directory for the session directory (shared with run
    /// directories by default).
    pub out_dir: String,
    /// How many runs may step concurrently (≥ 1). The shared threadpool
    /// is the real capacity limit; this bounds oversubscription.
    pub max_concurrent: usize,
    /// Status-line cadence in milliseconds (≥ 1).
    pub status_every_ms: u64,
    /// Bounded queue capacity for the `serve.jsonl` writer (lines).
    pub buffer: usize,
    /// Optional spool directory: `*.toml` scenario configs dropped here
    /// while the daemon runs are scheduled as they appear. With a
    /// spool, the daemon idles when drained instead of exiting.
    pub spool: Option<PathBuf>,
    /// Optional wall-clock deadline; reaching it triggers the same
    /// graceful shutdown as [`crate::serve::ServeHandle::shutdown`].
    pub max_seconds: Option<f64>,
    /// `--set k=v` config overrides applied to every scheduled run,
    /// including spooled ones (applied before validation).
    pub overrides: Vec<(String, String)>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            name: "serve".into(),
            out_dir: "runs".into(),
            max_concurrent: 2,
            status_every_ms: 500,
            buffer: 256,
            spool: None,
            max_seconds: None,
            overrides: Vec::new(),
        }
    }
}

impl ServeOptions {
    /// Bounds-check the options before the server starts.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("serve.name must be non-empty");
        }
        if self.max_concurrent == 0 {
            bail!("serve.max_concurrent must be >= 1");
        }
        if self.status_every_ms == 0 {
            bail!("serve.status_every_ms must be >= 1");
        }
        if self.buffer == 0 {
            bail!("serve.buffer must be >= 1");
        }
        if let Some(s) = self.max_seconds {
            if !s.is_finite() || s <= 0.0 {
                bail!("serve.max_seconds must be > 0");
            }
        }
        Ok(())
    }
}

/// An ordered batch of [`RunSpec`]s ready to enqueue.
#[derive(Debug, Clone, Default)]
pub struct Fleet {
    /// Runs in scheduling order (fleet-file order).
    pub specs: Vec<RunSpec>,
}

impl Fleet {
    /// Load a fleet spec file: parses the `[serve]` options, loads every
    /// `[fleet] configs` entry relative to the spec's directory, applies
    /// `overrides` to each, and validates each run eagerly.
    ///
    /// Unknown keys are an error (same policy as
    /// [`Config::from_file`]): a typo must not silently change what a
    /// nightly fleet trains.
    pub fn from_file(
        path: &Path,
        overrides: &[(String, String)],
    ) -> Result<(Fleet, ServeOptions)> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading fleet spec {}: {e}", path.display()))?;
        let map = parse_toml(&text)
            .map_err(|e| anyhow!("parsing fleet spec {}: {e}", path.display()))?;

        let mut opts = ServeOptions {
            overrides: overrides.to_vec(),
            ..ServeOptions::default()
        };
        let mut config_names: Vec<String> = Vec::new();
        for (key, val) in &map {
            match key.as_str() {
                "serve.name" => {
                    opts.name = val
                        .as_str()
                        .ok_or_else(|| anyhow!("serve.name must be a string"))?
                        .to_string();
                }
                "serve.out_dir" => {
                    opts.out_dir = val
                        .as_str()
                        .ok_or_else(|| anyhow!("serve.out_dir must be a string"))?
                        .to_string();
                }
                "serve.max_concurrent" => {
                    opts.max_concurrent = val
                        .as_usize()
                        .ok_or_else(|| anyhow!("serve.max_concurrent must be an integer"))?;
                }
                "serve.status_every_ms" => {
                    opts.status_every_ms = val
                        .as_usize()
                        .ok_or_else(|| anyhow!("serve.status_every_ms must be an integer"))?
                        as u64;
                }
                "serve.buffer" => {
                    opts.buffer = val
                        .as_usize()
                        .ok_or_else(|| anyhow!("serve.buffer must be an integer"))?;
                }
                "serve.spool" => {
                    let s = val
                        .as_str()
                        .ok_or_else(|| anyhow!("serve.spool must be a string"))?;
                    opts.spool = Some(resolve(path, s));
                }
                "serve.max_seconds" => {
                    opts.max_seconds = Some(
                        val.as_f64()
                            .ok_or_else(|| anyhow!("serve.max_seconds must be a number"))?,
                    );
                }
                "fleet.configs" => {
                    config_names = val.as_str_list().ok_or_else(|| {
                        anyhow!("fleet.configs must be a list of strings")
                    })?;
                }
                other => bail!(
                    "unknown key '{other}' in fleet spec {} (see docs/serving.md)",
                    path.display()
                ),
            }
        }
        opts.validate()?;

        let mut specs = Vec::with_capacity(config_names.len());
        for name in &config_names {
            let cfg_path = resolve(path, name);
            let mut cfg = Config::from_file(&cfg_path)?;
            cfg.apply_overrides(overrides)?;
            let spec = RunSpec::new(cfg);
            spec.validate()
                .map_err(|e| anyhow!("fleet entry {}: {e}", cfg_path.display()))?;
            specs.push(spec);
        }
        Ok((Fleet { specs }, opts))
    }

    /// Load one spooled scenario config (a plain `Config` TOML dropped
    /// into the spool directory), applying the daemon's overrides.
    pub fn load_spooled(
        path: &Path,
        overrides: &[(String, String)],
    ) -> Result<RunSpec> {
        let mut cfg = Config::from_file(path)?;
        cfg.apply_overrides(overrides)?;
        let spec = RunSpec::new(cfg);
        spec.validate()
            .map_err(|e| anyhow!("spooled config {}: {e}", path.display()))?;
        Ok(spec)
    }
}

/// Resolve `name` relative to the directory containing `spec_path`
/// (absolute paths pass through).
fn resolve(spec_path: &Path, name: &str) -> PathBuf {
    let p = Path::new(name);
    if p.is_absolute() {
        p.to_path_buf()
    } else {
        spec_path.parent().unwrap_or(Path::new(".")).join(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write(dir: &Path, name: &str, text: &str) -> PathBuf {
        let p = dir.join(name);
        std::fs::write(&p, text).unwrap();
        p
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pegrad_fleet_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    const RUN_TOML: &str = r#"
        run_name = "tiny"
        mode = "rust_pegrad"
        steps = 4
        [model]
        dims = [16, 8, 10]
        m = 8
    "#;

    #[test]
    fn loads_fleet_relative_to_spec() {
        let d = tmpdir("rel");
        write(&d, "tiny.toml", RUN_TOML);
        let spec = write(
            &d,
            "fleet.toml",
            r#"
            [serve]
            name = "smoke"
            max_concurrent = 3
            status_every_ms = 50
            [fleet]
            configs = ["tiny.toml", "tiny.toml"]
            "#,
        );
        let (fleet, opts) = Fleet::from_file(&spec, &[]).unwrap();
        assert_eq!(opts.name, "smoke");
        assert_eq!(opts.max_concurrent, 3);
        assert_eq!(opts.status_every_ms, 50);
        assert_eq!(fleet.specs.len(), 2);
        assert_eq!(fleet.specs[0].name, "tiny");
        assert_eq!(fleet.specs[0].config.steps, 4);
    }

    #[test]
    fn overrides_reach_every_run() {
        let d = tmpdir("ovr");
        write(&d, "tiny.toml", RUN_TOML);
        let spec = write(&d, "fleet.toml", "[fleet]\nconfigs = [\"tiny.toml\"]\n");
        let ov = vec![("steps".to_string(), "9".to_string())];
        let (fleet, _) = Fleet::from_file(&spec, &ov).unwrap();
        assert_eq!(fleet.specs[0].config.steps, 9);
    }

    #[test]
    fn rejects_unknown_keys_and_non_engine_modes() {
        let d = tmpdir("bad");
        write(&d, "tiny.toml", RUN_TOML);
        let spec = write(&d, "fleet.toml", "[serve]\nnmae = \"x\"\n");
        let err = Fleet::from_file(&spec, &[]).unwrap_err().to_string();
        assert!(err.contains("unknown key"), "{err}");

        let ov = vec![("mode".to_string(), "vanilla".to_string())];
        let spec2 = write(&d, "fleet2.toml", "[fleet]\nconfigs = [\"tiny.toml\"]\n");
        let err = Fleet::from_file(&spec2, &ov).unwrap_err().to_string();
        assert!(err.contains("rust-engine"), "{err}");
    }

    #[test]
    fn spooled_config_loads_and_validates() {
        let d = tmpdir("spool");
        let p = write(&d, "drop.toml", RUN_TOML);
        let spec = Fleet::load_spooled(&p, &[]).unwrap();
        assert_eq!(spec.name, "tiny");
        assert!(spec.panic_after.is_none());
    }
}
