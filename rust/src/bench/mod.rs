//! Criterion-replacement micro/macro benchmark harness (DESIGN.md §6) and
//! the report emitters the E1-E7 benches share.

pub mod harness;
pub mod report;

pub use harness::{bench_fn, BenchResult, BenchSpec};
pub use report::Table;
