//! Criterion-replacement micro/macro benchmark harness (DESIGN.md §6) and
//! the report emitters the E1-E7 benches share.
//!
//! (System map: `docs/architecture.md`.)

pub mod harness;
pub mod report;

pub use harness::{bench_fn, BenchResult, BenchSpec};
pub use report::Table;

/// Absolute path under the WORKSPACE root (one level above this
/// package). Bench artifacts (`BENCH_*.json`, `bench_results/`) belong
/// there regardless of the invoking working directory — `cargo bench`
/// runs bench binaries with cwd = the package root (`rust/`), while the
/// CI perf gate and the artifact upload read from the repo root.
pub fn workspace_path(rel: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .unwrap_or_else(|| std::path::Path::new("."))
        .join(rel)
}
