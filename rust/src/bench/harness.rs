//! Measurement core: warmup, adaptive iteration count, trimmed stats.

use crate::util::stats::Summary;
use crate::util::Timer;

/// How to run one benchmark.
#[derive(Debug, Clone)]
pub struct BenchSpec {
    /// seconds of warmup before measuring.
    pub warmup_secs: f64,
    /// target measurement time; iterations adapt to fill it.
    pub measure_secs: f64,
    /// hard bounds on sample count.
    pub min_samples: usize,
    /// Hard upper bound on sample count.
    pub max_samples: usize,
}

impl Default for BenchSpec {
    fn default() -> Self {
        BenchSpec {
            warmup_secs: 0.3,
            measure_secs: 1.5,
            min_samples: 5,
            max_samples: 200,
        }
    }
}

impl BenchSpec {
    /// Fast profile for CI / tests.
    pub fn quick() -> BenchSpec {
        BenchSpec {
            warmup_secs: 0.05,
            measure_secs: 0.2,
            min_samples: 3,
            max_samples: 20,
        }
    }
}

/// One benchmark's outcome (times in seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Per-iteration timing summary (seconds).
    pub summary: Summary,
}

impl BenchResult {
    /// Mean iteration time in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.summary.mean * 1e3
    }

    /// Median iteration time in milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.summary.p50 * 1e3
    }
}

/// Benchmark a closure: warmup until `warmup_secs` elapse, then collect
/// samples until `measure_secs` elapse (within sample-count bounds).
pub fn bench_fn(name: &str, spec: &BenchSpec, mut f: impl FnMut()) -> BenchResult {
    // warmup
    let w = Timer::start();
    let mut warm_iters = 0u64;
    while w.secs() < spec.warmup_secs || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    // measure
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < spec.max_samples
        && (samples.len() < spec.min_samples || total.secs() < spec.measure_secs)
    {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&samples),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_sleep_accurately() {
        let spec = BenchSpec {
            warmup_secs: 0.0,
            measure_secs: 0.1,
            min_samples: 5,
            max_samples: 10,
        };
        let r = bench_fn("sleep", &spec, || {
            std::thread::sleep(std::time::Duration::from_millis(2))
        });
        assert!(r.summary.p50 >= 0.002, "{:?}", r.summary);
        assert!(r.summary.p50 < 0.02, "{:?}", r.summary);
        assert!(r.summary.n >= 5);
    }

    #[test]
    fn respects_sample_bounds() {
        let spec = BenchSpec {
            warmup_secs: 0.0,
            measure_secs: 0.01,
            min_samples: 7,
            max_samples: 9,
        };
        let r = bench_fn("noop", &spec, || { std::hint::black_box(1 + 1); });
        assert!((7..=9).contains(&r.summary.n), "{}", r.summary.n);
    }
}
