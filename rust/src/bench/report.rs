//! Markdown/CSV table emitter for the experiment benches — every E-series
//! bench prints its paper-shaped table through this.

use std::fmt::Write as _;

/// A simple column-aligned table that renders to markdown and CSV.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title.
    pub title: String,
    /// Column headers.
    pub columns: Vec<String>,
    /// Row cells, aligned with `columns`.
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the column count).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n### {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let parts: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            format!("| {} |", parts.join(" | "))
        };
        let _ = writeln!(out, "{}", fmt_row(&self.columns, &widths));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "{}", fmt_row(&sep, &widths));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (title excluded).
    pub fn to_csv(&self) -> String {
        let mut out = self.columns.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and optionally save CSV next to it.
    pub fn emit(&self, csv_path: Option<&std::path::Path>) {
        println!("{}", self.to_markdown());
        if let Some(p) = csv_path {
            if let Some(dir) = p.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(p, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", p.display());
            } else {
                println!("(csv saved to {})", p.display());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv_shape() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| 333 |"));
        let csv = t.to_csv();
        assert_eq!(csv, "a,bb\n1,2\n333,4\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
