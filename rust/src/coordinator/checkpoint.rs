//! Binary checkpointing: params, optimizer state, RNG, step counter,
//! (since v2) the adaptive-clip controller state, and (since v3) the
//! outlier detector's persistent flag counts.
//!
//! Format (little-endian):
//! ```text
//! magic "PEGD" | u32 version | u64 step | [u64;4] rng state
//! | u32 n_params  | n_params  tensors
//! | u32 n_opt     | n_opt     tensors
//! | u32 has_clip  | has_clip == 1 ? clip state : nothing     (v2+)
//! | u32 has_flags | has_flags == 1 ? flag state : nothing    (v3+)
//! tensor := u32 rank | u64 dims[rank] | f32 data[numel]
//! clip   := f64 p | f64 q[5] | f64 n[5] | f64 np[5] | u64 count
//!         | f64 c | f64 init_c | u64 steps
//! flags  := u32 n | u32 counts[n] | u64 steps | u64 total_flags
//! ```
//!
//! Older files still load: a v1 checkpoint (no clip section) resumes
//! with `clip = None` exactly as before, a v2 checkpoint (no flags
//! section) with `flags = None` — the detector simply restarts its flag
//! history, the same behavior those builds always had. Only the
//! persistent flag COUNTS are checkpointed, not the running P²/Welford
//! threshold statistics: those re-warm within `warmup_steps`, while a
//! reset flag history would silently skew a `pegrad audit` ranking
//! across a resume.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::telemetry::adaptive::ClipState;
use crate::telemetry::sketch::P2State;
use crate::telemetry::FlagState;
use crate::tensor::{Rng, Tensor};

const MAGIC: &[u8; 4] = b"PEGD";
const VERSION: u32 = 3;

/// Everything needed to resume a run bitwise: saved on step boundaries
/// before any RNG lookahead (PEGD binary format, version-checked).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Step count completed when the checkpoint was taken.
    pub step: u64,
    /// Training RNG state at the step boundary.
    pub rng_state: [u64; 4],
    /// Model parameters, in layer order.
    pub params: Vec<Tensor>,
    /// Optimizer state tensors (empty for plain SGD).
    pub opt_state: Vec<Tensor>,
    /// Adaptive-clip controller dynamics; `None` on fixed-`C` runs and
    /// when loading a v1 file.
    pub clip: Option<ClipState>,
    /// Outlier-detector persistent flag counts (the audit ranking);
    /// `None` on telemetry-off runs and when loading a v1/v2 file.
    pub flags: Option<FlagState>,
}

impl Checkpoint {
    /// Checkpoint of the core training state (no clip/flag extensions).
    pub fn new(step: u64, rng: &Rng, params: Vec<Tensor>, opt_state: Vec<Tensor>) -> Self {
        Checkpoint {
            step,
            rng_state: rng.state(),
            params,
            opt_state,
            clip: None,
            flags: None,
        }
    }

    /// Attach adaptive-clip controller state (builder-style).
    pub fn with_clip(mut self, clip: Option<ClipState>) -> Self {
        self.clip = clip;
        self
    }

    /// Attach outlier-detector flag counts (builder-style, v3).
    pub fn with_flags(mut self, flags: Option<FlagState>) -> Self {
        self.flags = flags;
        self
    }

    /// Serialize to the on-disk byte format. This is the hot-path half
    /// of an asynchronous save: rendering is pure memory work, so a
    /// trainer can serialize inline and hand the bytes to the
    /// [`trace::BlobWriter`](crate::trace::BlobWriter) thread, which
    /// owns the disk (write-temp-then-rename, exactly like
    /// [`Checkpoint::save`]).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out: Vec<u8> = Vec::new();
        self.write_into(&mut out)
            .expect("serializing a checkpoint into memory cannot fail");
        out
    }

    fn write_into<W: Write>(&self, f: &mut W) -> Result<()> {
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        for s in self.rng_state {
            f.write_all(&s.to_le_bytes())?;
        }
        write_tensors(f, &self.params)?;
        write_tensors(f, &self.opt_state)?;
        match &self.clip {
            None => f.write_all(&0u32.to_le_bytes())?,
            Some(cs) => {
                f.write_all(&1u32.to_le_bytes())?;
                write_clip(f, cs)?;
            }
        }
        match &self.flags {
            None => f.write_all(&0u32.to_le_bytes())?,
            Some(fl) => {
                f.write_all(&1u32.to_le_bytes())?;
                write_flags(f, fl)?;
            }
        }
        Ok(())
    }

    /// Synchronous save: serialize, then write-temp-and-rename (a crash
    /// mid-write must not destroy the previous checkpoint).
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::trace::writer::write_blob_atomic(path, &self.to_bytes())?;
        Ok(())
    }

    /// Load and validate a PEGD file (v1–v3 accepted).
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f =
            fs::File::open(path).map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a pegrad checkpoint", path.display());
        }
        let version = read_u32(&mut f)?;
        if !(1..=VERSION).contains(&version) {
            bail!("checkpoint version {version} not in supported range 1..={VERSION}");
        }
        let step = read_u64(&mut f)?;
        let mut rng_state = [0u64; 4];
        for s in &mut rng_state {
            *s = read_u64(&mut f)?;
        }
        let params = read_tensors(&mut f)?;
        let opt_state = read_tensors(&mut f)?;
        let clip = if version >= 2 {
            match read_u32(&mut f)? {
                0 => None,
                1 => Some(read_clip(&mut f)?),
                other => bail!("bad clip-section flag {other} (corrupt checkpoint?)"),
            }
        } else {
            None
        };
        let flags = if version >= 3 {
            match read_u32(&mut f)? {
                0 => None,
                1 => Some(read_flags(&mut f)?),
                other => bail!("bad flags-section flag {other} (corrupt checkpoint?)"),
            }
        } else {
            None
        };
        Ok(Checkpoint {
            step,
            rng_state,
            params,
            opt_state,
            clip,
            flags,
        })
    }

    /// Reconstruct the training RNG from the saved state.
    pub fn rng(&self) -> Rng {
        Rng::from_state(self.rng_state)
    }
}

fn write_tensors<W: Write>(f: &mut W, ts: &[Tensor]) -> Result<()> {
    f.write_all(&(ts.len() as u32).to_le_bytes())?;
    for t in ts {
        f.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.dims() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk-write the f32 slice
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.numel() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

fn read_tensors(f: &mut fs::File) -> Result<Vec<Tensor>> {
    let n = read_u32(f)? as usize;
    if n > 1 << 20 {
        bail!("implausible tensor count {n} (corrupt checkpoint?)");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = read_u32(f)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank} (corrupt checkpoint?)");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u64(f)? as usize);
        }
        let numel: usize = dims.iter().product();
        if numel > 1 << 31 {
            bail!("implausible tensor size (corrupt checkpoint?)");
        }
        let mut data = vec![0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        out.push(Tensor::new(dims, data));
    }
    Ok(out)
}

fn write_clip<W: Write>(f: &mut W, cs: &ClipState) -> Result<()> {
    f.write_all(&cs.sketch.p.to_le_bytes())?;
    for arr in [&cs.sketch.q, &cs.sketch.n, &cs.sketch.np] {
        for v in arr {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.write_all(&cs.sketch.count.to_le_bytes())?;
    f.write_all(&cs.c.to_le_bytes())?;
    f.write_all(&cs.init_c.to_le_bytes())?;
    f.write_all(&cs.steps.to_le_bytes())?;
    Ok(())
}

fn read_clip(f: &mut fs::File) -> Result<ClipState> {
    let p = read_f64(f)?;
    if !(p > 0.0 && p < 1.0) {
        bail!("implausible clip quantile {p} (corrupt checkpoint?)");
    }
    let mut arrs = [[0f64; 5]; 3];
    for arr in &mut arrs {
        for v in arr.iter_mut() {
            *v = read_f64(f)?;
        }
    }
    let [q, n, np] = arrs;
    let count = read_u64(f)?;
    let c = read_f64(f)?;
    let init_c = read_f64(f)?;
    let steps = read_u64(f)?;
    Ok(ClipState {
        sketch: P2State { p, q, n, np, count },
        c,
        init_c,
        steps,
    })
}

fn write_flags<W: Write>(f: &mut W, fs: &FlagState) -> Result<()> {
    f.write_all(&(fs.counts.len() as u32).to_le_bytes())?;
    for &c in &fs.counts {
        f.write_all(&c.to_le_bytes())?;
    }
    f.write_all(&fs.steps.to_le_bytes())?;
    f.write_all(&fs.total_flags.to_le_bytes())?;
    Ok(())
}

fn read_flags(f: &mut fs::File) -> Result<FlagState> {
    let n = read_u32(f)? as usize;
    if n > 1 << 28 {
        bail!("implausible flag-table size {n} (corrupt checkpoint?)");
    }
    let mut counts = Vec::with_capacity(n);
    for _ in 0..n {
        counts.push(read_u32(f)?);
    }
    let steps = read_u64(f)?;
    let total_flags = read_u64(f)?;
    Ok(FlagState {
        counts,
        steps,
        total_flags,
    })
}

fn read_u32(f: &mut fs::File) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut fs::File) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_f64(f: &mut fs::File) -> Result<f64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pegrad-ckpt-{}-{tag}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(9);
        let params = vec![
            Tensor::randn(vec![3, 4], &mut rng),
            Tensor::randn(vec![5], &mut rng),
        ];
        let opt = vec![Tensor::randn(vec![3, 4], &mut rng)];
        let ck = Checkpoint::new(42, &rng, params.clone(), opt.clone());
        let path = tmpfile("rt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params, params);
        assert_eq!(back.opt_state, opt);
        // rng resumes identically
        let mut r1 = rng.clone();
        let mut r2 = back.rng();
        assert_eq!(r1.next_u64(), r2.next_u64());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clip_state_roundtrips_bitwise() {
        use crate::telemetry::{ClipConfig, ClipController};
        let cfg = ClipConfig {
            adaptive: true,
            ..ClipConfig::default()
        };
        let mut ctrl = ClipController::new(&cfg, 0.8);
        for i in 0..25 {
            ctrl.observe_norms(&[1.0 + i as f32, 2.0, 0.5 * i as f32]);
        }
        let rng = Rng::new(7);
        let ck = Checkpoint::new(25, &rng, vec![], vec![]).with_clip(Some(ctrl.snapshot()));
        let path = tmpfile("clip");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let state = back.clip.expect("clip section lost");
        assert_eq!(state, ctrl.snapshot(), "clip state not bitwise after roundtrip");
        // a restored controller continues exactly like the original
        let mut resumed = ClipController::new(&cfg, 0.8);
        resumed.restore_state(&state);
        ctrl.observe_norms(&[3.0, 4.0]);
        resumed.observe_norms(&[3.0, 4.0]);
        assert_eq!(ctrl.bound().to_bits(), resumed.bound().to_bits());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version1_files_still_load_without_clip() {
        // hand-assemble a minimal v1 file: header + empty tensor lists,
        // no clip section
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes()); // version 1
        bytes.extend_from_slice(&17u64.to_le_bytes()); // step
        for s in Rng::new(3).state() {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_params
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_opt
        let path = tmpfile("v1");
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 17);
        assert!(back.clip.is_none(), "v1 file must load with clip = None");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flag_state_roundtrips_exactly() {
        use crate::telemetry::{OutlierConfig, OutlierDetector};
        let mut det = OutlierDetector::new(
            16,
            OutlierConfig {
                warmup_steps: 0,
                ..Default::default()
            },
        );
        for _ in 0..10 {
            det.observe(&[0, 1, 2], &[1.0, 1.0, 1.0]);
        }
        det.observe(&[7], &[1000.0]);
        let rng = Rng::new(5);
        let ck = Checkpoint::new(11, &rng, vec![], vec![]).with_flags(Some(det.flag_state()));
        let path = tmpfile("flags");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        let state = back.flags.expect("flags section lost");
        assert_eq!(state, det.flag_state(), "flag state not exact after roundtrip");
        // a restored detector ranks identically
        let mut resumed = OutlierDetector::new(16, OutlierConfig::default());
        resumed.restore_flags(&state);
        assert_eq!(resumed.top_flagged(4), det.top_flagged(4));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn version2_files_still_load_without_flags() {
        // hand-assemble a minimal v2 file: header + empty tensor lists +
        // empty clip section, no flags section
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes()); // version 2
        bytes.extend_from_slice(&23u64.to_le_bytes()); // step
        for s in Rng::new(3).state() {
            bytes.extend_from_slice(&s.to_le_bytes());
        }
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_params
        bytes.extend_from_slice(&0u32.to_le_bytes()); // n_opt
        bytes.extend_from_slice(&0u32.to_le_bytes()); // has_clip = 0
        let path = tmpfile("v2");
        std::fs::write(&path, &bytes).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 23);
        assert!(back.clip.is_none());
        assert!(back.flags.is_none(), "v2 file must load with flags = None");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn to_bytes_matches_the_file_save_writes() {
        let mut rng = Rng::new(4);
        let params = vec![Tensor::randn(vec![2, 3], &mut rng)];
        let ck = Checkpoint::new(7, &rng, params, vec![]);
        let path = tmpfile("bytes");
        ck.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), ck.to_bytes());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("bad");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_tensors_ok() {
        let rng = Rng::new(0);
        let ck = Checkpoint::new(0, &rng, vec![], vec![]);
        let path = tmpfile("empty");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert!(back.params.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn atomic_overwrite_preserves_on_rewrite() {
        let rng = Rng::new(0);
        let path = tmpfile("atomic");
        Checkpoint::new(1, &rng, vec![Tensor::ones(vec![2])], vec![])
            .save(&path)
            .unwrap();
        Checkpoint::new(2, &rng, vec![Tensor::zeros(vec![2])], vec![])
            .save(&path)
            .unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 2);
        let _ = std::fs::remove_file(&path);
    }
}
