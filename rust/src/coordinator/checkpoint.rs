//! Binary checkpointing: params, optimizer state, RNG, step counter.
//!
//! Format (little-endian):
//! ```text
//! magic "PEGD" | u32 version | u64 step | [u64;4] rng state
//! | u32 n_params  | n_params  tensors
//! | u32 n_opt     | n_opt     tensors
//! tensor := u32 rank | u64 dims[rank] | f32 data[numel]
//! ```

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::tensor::{Rng, Tensor};

const MAGIC: &[u8; 4] = b"PEGD";
const VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub step: u64,
    pub rng_state: [u64; 4],
    pub params: Vec<Tensor>,
    pub opt_state: Vec<Tensor>,
}

impl Checkpoint {
    pub fn new(step: u64, rng: &Rng, params: Vec<Tensor>, opt_state: Vec<Tensor>) -> Self {
        Checkpoint {
            step,
            rng_state: rng.state(),
            params,
            opt_state,
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        // write to a temp file then rename: a crash mid-write must not
        // destroy the previous checkpoint
        let tmp = path.with_extension("tmp");
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(MAGIC)?;
            f.write_all(&VERSION.to_le_bytes())?;
            f.write_all(&self.step.to_le_bytes())?;
            for s in self.rng_state {
                f.write_all(&s.to_le_bytes())?;
            }
            write_tensors(&mut f, &self.params)?;
            write_tensors(&mut f, &self.opt_state)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f =
            fs::File::open(path).map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a pegrad checkpoint", path.display());
        }
        let version = read_u32(&mut f)?;
        if version != VERSION {
            bail!("checkpoint version {version} != supported {VERSION}");
        }
        let step = read_u64(&mut f)?;
        let mut rng_state = [0u64; 4];
        for s in &mut rng_state {
            *s = read_u64(&mut f)?;
        }
        let params = read_tensors(&mut f)?;
        let opt_state = read_tensors(&mut f)?;
        Ok(Checkpoint {
            step,
            rng_state,
            params,
            opt_state,
        })
    }

    pub fn rng(&self) -> Rng {
        Rng::from_state(self.rng_state)
    }
}

fn write_tensors(f: &mut fs::File, ts: &[Tensor]) -> Result<()> {
    f.write_all(&(ts.len() as u32).to_le_bytes())?;
    for t in ts {
        f.write_all(&(t.rank() as u32).to_le_bytes())?;
        for &d in t.dims() {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk-write the f32 slice
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.numel() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

fn read_tensors(f: &mut fs::File) -> Result<Vec<Tensor>> {
    let n = read_u32(f)? as usize;
    if n > 1 << 20 {
        bail!("implausible tensor count {n} (corrupt checkpoint?)");
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = read_u32(f)? as usize;
        if rank > 8 {
            bail!("implausible rank {rank} (corrupt checkpoint?)");
        }
        let mut dims = Vec::with_capacity(rank);
        for _ in 0..rank {
            dims.push(read_u64(f)? as usize);
        }
        let numel: usize = dims.iter().product();
        if numel > 1 << 31 {
            bail!("implausible tensor size (corrupt checkpoint?)");
        }
        let mut data = vec![0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        out.push(Tensor::new(dims, data));
    }
    Ok(out)
}

fn read_u32(f: &mut fs::File) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut fs::File) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pegrad-ckpt-{}-{tag}.bin", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(9);
        let params = vec![
            Tensor::randn(vec![3, 4], &mut rng),
            Tensor::randn(vec![5], &mut rng),
        ];
        let opt = vec![Tensor::randn(vec![3, 4], &mut rng)];
        let ck = Checkpoint::new(42, &rng, params.clone(), opt.clone());
        let path = tmpfile("rt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.params, params);
        assert_eq!(back.opt_state, opt);
        // rng resumes identically
        let mut r1 = rng.clone();
        let mut r2 = back.rng();
        assert_eq!(r1.next_u64(), r2.next_u64());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmpfile("bad");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn empty_tensors_ok() {
        let rng = Rng::new(0);
        let ck = Checkpoint::new(0, &rng, vec![], vec![]);
        let path = tmpfile("empty");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert!(back.params.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn atomic_overwrite_preserves_on_rewrite() {
        let rng = Rng::new(0);
        let path = tmpfile("atomic");
        Checkpoint::new(1, &rng, vec![Tensor::ones(vec![2])], vec![])
            .save(&path)
            .unwrap();
        Checkpoint::new(2, &rng, vec![Tensor::zeros(vec![2])], vec![])
            .save(&path)
            .unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 2);
        let _ = std::fs::remove_file(&path);
    }
}
