//! The training loop.
//!
//! Per step:
//! 1. take the prefetched minibatch (gather overlaps the previous step's
//!    execution; the selection is at most one step stale w.r.t. norms);
//! 2. execute the mode's artifact — parameters stay device-resident for
//!    the fused modes, so per-step host traffic is batch-in / scalars-out;
//! 3. feed the fresh per-example norms back to the importance sampler
//!    (the paper's §1 loop) and the DP accountant (§6);
//! 4. metrics, periodic eval, periodic checkpoint.

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{Config, DataKind, OptimKind, RunMode, SamplerKind};
use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::{MetricsLogger, StepRecord};
use crate::data::loader::{prepare, PreparedBatch, Prefetcher};
use crate::data::{digits, regression, seq, synth, Dataset};
use crate::engine::{EngineMode, FusedEngine};
use crate::nn::loss::Targets;
use crate::nn::{Loss, Mlp, ModelSpec, StackSpec};
use crate::optim::{Adam, Optimizer, Sgd};
use crate::privacy::RdpAccountant;
use crate::runtime::executable::{fetch_f32, Arg, Entry};
use crate::runtime::{Manifest, Registry};
use crate::sampler::{
    Batch, ImportanceConfig, ImportanceSampler, Sampler, UniformSampler,
};
use crate::telemetry::{ClipController, LayerTap, SaliencyTap, TeeTap, TelemetryMonitor};
use crate::tensor::{ops, Rng, Tensor};
use crate::trace::{BlobWriter, StreamWriter};
use crate::util::threadpool::{bounded, BoundedSender};
use crate::util::Timer;

/// Final numbers a run reports (EXPERIMENTS.md rows come from this).
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Steps completed.
    pub steps: usize,
    /// Training loss of the last step.
    pub final_loss: f32,
    /// Final eval-set loss, if an eval ran.
    pub eval_loss: Option<f32>,
    /// Final eval-set accuracy (classification runs only).
    pub eval_accuracy: Option<f32>,
    /// Mean wall-clock step latency in milliseconds.
    pub mean_step_ms: f64,
    /// (step, train loss) every step — the loss curve.
    pub curve: Vec<(usize, f32)>,
    /// (ε, δ) at the end, for clipped runs.
    pub epsilon: Option<f64>,
    /// Where the final telemetry report landed (`[telemetry]` runs only).
    pub telemetry_path: Option<std::path::PathBuf>,
}

/// Owns everything a run needs. Single-threaded w.r.t. PJRT (see module
/// docs); the gather prefetcher is the only helper thread.
pub struct Trainer {
    /// The validated run configuration.
    pub cfg: Config,
    /// The model as a heterogeneous layer stack — the shape source of
    /// truth for every mode (dense models map onto dense-only stacks).
    pub stack: StackSpec,
    /// The dense `ModelSpec` view, when the model is expressible as one
    /// (always for artifact modes; `None` for conv stacks).
    dense_spec: Option<ModelSpec>,
    /// Artifact registry — `None` for the rust-engine modes, which need
    /// neither the PJRT runtime nor AOT artifacts.
    registry: Option<Registry>,
    /// The fused streaming engine — `Some` exactly for the rust modes.
    engine: Option<FusedEngine>,
    train: Dataset,
    eval: Dataset,
    sampler: Box<dyn Sampler>,
    rng: Rng,
    /// Host mirror of the parameters (source of truth for RustOptim mode;
    /// refreshed from device on checkpoint/finish for fused modes).
    params: Vec<Tensor>,
    /// Device-resident parameters (fused modes).
    dev_params: Option<Vec<xla::PjRtBuffer>>,
    optimizer: Box<dyn Optimizer>,
    accountant: Option<RdpAccountant>,
    /// Streaming gradient-norm telemetry (`[telemetry]` section; rust
    /// modes only — the monitor taps the fused engine's backward pass).
    monitor: Option<TelemetryMonitor>,
    /// Adaptive quantile-tracked clip bound (`[clip]` section; rust
    /// modes only). Fed from the same engine tap stream as the monitor;
    /// actuates the §6 bound in `rust_clipped` (and the target in
    /// `rust_normalized`), observation-only under `rust_pegrad`.
    clip: Option<ClipController>,
    /// Per-position saliency accumulator (`[audit]` section; rust modes
    /// only). Tees onto the same engine tap stream; tracks EMA maps for
    /// the outlier detector's top-N flagged examples.
    saliency: Option<SaliencyTap>,
    /// Saliency map dump paths from the end of the last `run()`
    /// (`[audit]` runs only; `pegrad audit` records them in audit.json).
    pub saliency_maps: Vec<std::path::PathBuf>,
    /// Metrics sink (`metrics.jsonl` + `.csv`, or a null logger).
    pub metrics: MetricsLogger,
    step: usize,
    /// L3-vs-L2 step-time breakdown, filled when `PEGRAD_PROFILE=1`
    /// (§Perf evidence: the coordinator must not be the bottleneck).
    pub profile: Option<Profile>,
}

/// Accumulated per-phase wall time across a run (seconds).
#[derive(Debug, Clone, Default)]
pub struct Profile {
    /// Seconds spent uploading host buffers to the device.
    pub upload: f64,
    /// Seconds spent inside the step computation.
    pub execute: f64,
    /// Seconds spent fetching results back to the host.
    pub fetch: f64,
    /// Seconds spent sampling indices and gathering the batch.
    pub sample_gather: f64,
    /// Steps the breakdown covers.
    pub steps: u64,
}

impl Profile {
    /// One-line percentage breakdown for the log.
    pub fn report(&self) -> String {
        let total = self.upload + self.execute + self.fetch + self.sample_gather;
        let pct = |x: f64| 100.0 * x / total.max(1e-12);
        format!(
            "breakdown over {} steps: execute {:.1}% | upload {:.1}% | fetch {:.1}% | sample+gather {:.1}%  (L3 overhead {:.2}%)",
            self.steps,
            pct(self.execute),
            pct(self.upload),
            pct(self.fetch),
            pct(self.sample_gather),
            pct(total - self.execute)
        )
    }
}

/// Everything a run holds OPEN while it trains: JSONL stream writers,
/// the gather-prefetch pipeline, the trace recorder, the asynchronous
/// checkpoint writer, and the loss curve. Created by
/// [`Trainer::begin_session`], advanced one step at a time by
/// [`Trainer::step_session`], consumed by [`Trainer::finish_session`].
///
/// [`Trainer::run`] drives these three for the one-shot CLI; the
/// `serve` scheduler drives them directly so it can interleave many
/// concurrent runs over the shared threadpool and stop any of them at
/// a clean step boundary (graceful shutdown). Every resource here is
/// per-run — two sessions on two threads share nothing but the global
/// threadpool and the process-wide trace counters.
pub struct RunSession {
    entry: Option<std::rc::Rc<Entry>>,
    fwd_entry: Option<std::rc::Rc<Entry>>,
    total: Timer,
    tracing: bool,
    recorder: Option<crate::trace::Recorder>,
    trace_writer: Option<StreamWriter>,
    telemetry_writer: Option<StreamWriter>,
    saliency_writer: Option<StreamWriter>,
    /// Periodic checkpoints render on the hot path (memory-bound) and
    /// land on disk via this writer thread — the step loop never waits
    /// on checkpoint I/O.
    ckpt_writer: Option<BlobWriter>,
    sel_tx: Option<BoundedSender<(usize, Batch)>>,
    prefetcher: Option<Prefetcher>,
    pending: Option<PreparedBatch>,
    curve: Vec<(usize, f32)>,
    end_step: usize,
    stopped: bool,
}

impl RunSession {
    /// The step index this session runs to (exclusive).
    pub fn end_step(&self) -> usize {
        self.end_step
    }

    /// Steps executed so far in THIS session.
    pub fn steps_executed(&self) -> usize {
        self.curve.len()
    }

    /// True once an early `stop` completed: the session executed its
    /// final step and only [`Trainer::finish_session`] remains.
    pub fn stopped(&self) -> bool {
        self.stopped
    }
}

impl Trainer {
    /// Build a trainer from a validated config: datasets, model, engine
    /// or runtime, sampler, optimizer and telemetry taps.
    pub fn new(cfg: Config) -> Result<Trainer> {
        cfg.validate()?;
        let (registry, dense_spec, stack) = if cfg.mode.is_rust_engine() {
            // model straight from config; no manifest, no PJRT
            let loss = Loss::parse(&cfg.model_loss)
                .ok_or_else(|| anyhow!("unknown model.loss '{}'", cfg.model_loss))?;
            if !cfg.model_stack.is_empty() {
                let stack = StackSpec::parse(&cfg.model_stack, loss, cfg.model_m)?;
                (None, None, stack)
            } else {
                let act = ops::Activation::parse(&cfg.model_activation).ok_or_else(
                    || anyhow!("unknown model.activation '{}'", cfg.model_activation),
                )?;
                let spec = ModelSpec::new(cfg.model_dims.clone(), act, loss, cfg.model_m)?;
                let stack = StackSpec::from_dense(&spec);
                (None, Some(spec), stack)
            }
        } else {
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            let registry = Registry::new(manifest);
            let spec = registry.manifest.preset(&cfg.preset)?.spec()?;
            let stack = StackSpec::from_dense(&spec);
            (Some(registry), Some(spec), stack)
        };
        let mut engine = cfg
            .mode
            .is_rust_engine()
            .then(|| FusedEngine::from_stack(stack.clone()));
        if cfg.audit.enabled {
            // validated: audit requires a rust-engine mode + telemetry
            engine
                .as_mut()
                .expect("validated: audit requires a rust-engine mode")
                .enable_saliency();
        }

        let mut rng = Rng::new(cfg.seed);
        let (train, eval) = build_datasets(&cfg, &stack, &mut rng)?;
        log::info!(
            "dataset: {} train={} eval={}  model: {} ({} params, m={})",
            train.name,
            train.len(),
            eval.len(),
            if cfg.model_stack.is_empty() {
                cfg.preset.clone()
            } else {
                cfg.model_stack.clone()
            },
            stack.param_count(),
            stack.m
        );

        let sampler: Box<dyn Sampler> = match cfg.sampler {
            SamplerKind::Uniform => Box::new(UniformSampler::new(train.len())),
            SamplerKind::Importance => Box::new(ImportanceSampler::new(
                train.len(),
                ImportanceConfig {
                    ema_lambda: cfg.sampler_lambda,
                    floor: cfg.sampler_floor,
                    ..Default::default()
                },
            )),
        };

        let optimizer: Box<dyn Optimizer> = match cfg.optim {
            OptimKind::Sgd => Box::new(Sgd::plain()),
            OptimKind::Momentum => Box::new(Sgd::new(0.9, true, 0.0)),
            OptimKind::Adam => Box::new(Adam::default()),
        };

        let accountant = cfg.privacy.as_ref().map(|p| {
            let q = (stack.m as f64 / train.len() as f64).min(1.0);
            let mut a = RdpAccountant::new(q, p.noise_sigma.max(1e-6) as f64);
            a.observe_steps(0);
            a
        });

        // dense models keep the exact ModelSpec init (He/Glorot chosen by
        // the model activation); stacks choose per layer
        let params = match &dense_spec {
            Some(spec) => spec.init_params(&mut rng),
            None => stack.init_params(&mut rng),
        };
        let mut monitor = cfg.telemetry.enabled.then(|| {
            let mut mon =
                TelemetryMonitor::new(&cfg.telemetry, stack.n_params(), stack.m, train.len());
            // the GNS decomposition is unbiased only for the plain uniform
            // minibatch mean; IS weights and the §6 rescales shift both
            // moments, so the report must say which one it is
            if cfg.sampler != SamplerKind::Uniform || cfg.mode != RunMode::RustPegrad {
                mon.mark_weighted_gradients();
            }
            mon
        });
        if cfg.telemetry.norm_layers_only {
            let mask = norm_layer_mask(&stack);
            engine
                .as_mut()
                .expect("validated: telemetry requires a rust-engine mode")
                .set_tap_mask(Some(mask.clone()));
            if let Some(mon) = monitor.as_mut() {
                mon.set_layer_mask(Some(mask));
            }
        }
        let clip = cfg.clip.adaptive.then(|| {
            // the initial bound is whatever the mode would have used as
            // its fixed constant; the controller starts there and the
            // warmup keeps it there until the sketch is populated
            let init_c = match cfg.mode {
                RunMode::RustClipped => cfg.privacy.as_ref().expect("validated").clip_c,
                RunMode::RustNormalized => cfg.normalize_target,
                // observation-only (Mean mode): no fixed bound exists to
                // inherit, so start inside the guard band — keeps
                // init_bound()/history[0] consistent in the reports
                _ => 1.0f32.clamp(cfg.clip.c_min, cfg.clip.c_max),
            };
            ClipController::new(&cfg.clip, init_c)
        });
        let saliency = cfg
            .audit
            .enabled
            .then(|| SaliencyTap::new(&stack.map_shapes(), stack.m, &cfg.audit));
        let metrics = MetricsLogger::new(&cfg.out_dir, &cfg.run_name, 25)?;
        let profile = std::env::var("PEGRAD_PROFILE")
            .ok()
            .filter(|v| v == "1")
            .map(|_| Profile::default());
        Ok(Trainer {
            cfg,
            stack,
            dense_spec,
            registry,
            engine,
            train,
            eval,
            sampler,
            rng,
            params,
            dev_params: None,
            optimizer,
            accountant,
            monitor,
            clip,
            saliency,
            saliency_maps: Vec::new(),
            metrics,
            step: 0,
            profile,
        })
    }

    /// [`Trainer::new`] minus the given training examples: the audit
    /// retrain phase. The train split is generated identically (same
    /// seed, same distribution), then the excluded dataset indices are
    /// dropped; the sampler, telemetry flag table and accountant are
    /// rebuilt for the smaller set. Eval stays untouched so the quality
    /// delta compares like with like.
    pub fn new_pruned(cfg: Config, excluded: &[usize]) -> Result<Trainer> {
        let mut tr = Trainer::new(cfg)?;
        if excluded.is_empty() {
            return Ok(tr);
        }
        let keep: Vec<usize> = (0..tr.train.len())
            .filter(|i| !excluded.contains(i))
            .collect();
        if keep.len() < tr.stack.m {
            bail!(
                "pruning {} examples leaves {} < m = {} training rows",
                excluded.len(),
                keep.len(),
                tr.stack.m
            );
        }
        tr.train = tr
            .train
            .subset(&keep, format!("{}-pruned", tr.train.name));
        tr.sampler = match tr.cfg.sampler {
            SamplerKind::Uniform => Box::new(UniformSampler::new(tr.train.len())),
            SamplerKind::Importance => Box::new(ImportanceSampler::new(
                tr.train.len(),
                ImportanceConfig {
                    ema_lambda: tr.cfg.sampler_lambda,
                    floor: tr.cfg.sampler_floor,
                    ..Default::default()
                },
            )),
        };
        if tr.monitor.is_some() {
            let mut mon = TelemetryMonitor::new(
                &tr.cfg.telemetry,
                tr.stack.n_params(),
                tr.stack.m,
                tr.train.len(),
            );
            if tr.cfg.sampler != SamplerKind::Uniform || tr.cfg.mode != RunMode::RustPegrad {
                mon.mark_weighted_gradients();
            }
            if tr.cfg.telemetry.norm_layers_only {
                mon.set_layer_mask(Some(norm_layer_mask(&tr.stack)));
            }
            tr.monitor = Some(mon);
        }
        if let Some(p) = tr.cfg.privacy.as_ref() {
            let q = (tr.stack.m as f64 / tr.train.len() as f64).min(1.0);
            let mut a = RdpAccountant::new(q, p.noise_sigma.max(1e-6) as f64);
            a.observe_steps(0);
            tr.accountant = Some(a);
        }
        Ok(tr)
    }

    /// The live telemetry monitor, when `[telemetry]` is enabled.
    pub fn telemetry(&self) -> Option<&TelemetryMonitor> {
        self.monitor.as_ref()
    }

    /// The live adaptive clip controller, when `[clip] adaptive = true`.
    pub fn clip_controller(&self) -> Option<&ClipController> {
        self.clip.as_ref()
    }

    /// The live saliency tap, when `[audit]` is enabled.
    pub fn saliency(&self) -> Option<&SaliencyTap> {
        self.saliency.as_ref()
    }

    /// Evaluate the CURRENT parameters on the eval split (rust-engine
    /// modes only — the audit pipeline's before/after quality probe).
    pub fn evaluate_now(&mut self) -> Result<(f32, Option<f32>)> {
        if !self.cfg.mode.is_rust_engine() {
            bail!("evaluate_now supports the rust-engine modes only");
        }
        self.evaluate(None)
    }

    /// Resume parameters/step/rng from a checkpoint.
    pub fn restore(&mut self, ck: Checkpoint) -> Result<()> {
        if ck.params.len() != self.params.len() {
            bail!(
                "checkpoint has {} param tensors, model needs {}",
                ck.params.len(),
                self.params.len()
            );
        }
        for (a, b) in ck.params.iter().zip(&self.params) {
            if a.dims() != b.dims() {
                bail!("checkpoint shape mismatch: {:?} vs {:?}", a.dims(), b.dims());
            }
        }
        self.params = ck.params.clone();
        if !ck.opt_state.is_empty() {
            self.optimizer.load_state(ck.opt_state.clone());
        }
        self.rng = ck.rng();
        self.step = ck.step as usize;
        if let (Some(ctrl), Some(state)) = (self.clip.as_mut(), ck.clip.as_ref()) {
            // resume the adaptive bound where the run left it: sketch
            // markers, current C, and step count all carry over, so the
            // bound sequence matches an uninterrupted run bitwise. A v1
            // (or fixed-C) checkpoint has no state — the controller
            // simply restarts its warmup from the initial bound.
            ctrl.restore_state(state);
        }
        if let (Some(mon), Some(fl)) = (self.monitor.as_mut(), ck.flags.as_ref()) {
            // resume the persistent audit flag counts (v3): the ranking
            // carries over; the threshold statistics deliberately re-warm
            // (see coordinator::checkpoint module docs). A v1/v2 file has
            // no flags — the detector restarts its history as before.
            mon.outliers_mut().restore_flags(fl);
        }
        self.dev_params = None; // re-upload lazily
        Ok(())
    }

    fn entry_name(&self) -> &'static str {
        match self.cfg.mode {
            RunMode::Vanilla => "step_vanilla",
            RunMode::Pegrad => "step_pegrad",
            RunMode::RustOptim => "grads_pegrad",
            RunMode::Clipped => "step_clipped",
            RunMode::RustPegrad | RunMode::RustClipped | RunMode::RustNormalized => {
                unreachable!("rust-engine modes compile no artifacts")
            }
        }
    }

    /// Upload params to device if not already there (fused modes).
    fn ensure_dev_params(&mut self) -> Result<()> {
        if self.dev_params.is_none() {
            let c = crate::runtime::client::global();
            let bufs = self
                .params
                .iter()
                .map(|t| {
                    c.buffer_from_host_buffer(t.data(), t.dims(), None)
                        .map_err(|e| anyhow!("param upload: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;
            self.dev_params = Some(bufs);
        }
        Ok(())
    }

    /// Pull device params back into the host mirror.
    fn sync_params_to_host(&mut self) -> Result<()> {
        if let Some(bufs) = &self.dev_params {
            self.params = bufs.iter().map(fetch_f32).collect::<Result<Vec<_>>>()?;
        }
        Ok(())
    }

    /// Run the configured number of steps; returns the summary.
    ///
    /// Thin wrapper over the session API — open a [`RunSession`], step
    /// it to exhaustion, finish it. The `serve` scheduler calls the
    /// same three pieces directly so it can interleave many concurrent
    /// runs and stop any of them at a clean step boundary.
    pub fn run(&mut self) -> Result<RunSummary> {
        let mut session = self.begin_session()?;
        while self.step_session(&mut session, false)? {}
        self.finish_session(session)
    }

    /// Open a training session: resolve artifact entries, start the
    /// per-run stream writers and the asynchronous checkpoint writer,
    /// spin up the gather-prefetch pipeline and prime it with the
    /// first selection. Every resource lands in the returned
    /// [`RunSession`]; nothing global is touched except the process
    /// trace toggle (when `[trace] enabled`).
    pub fn begin_session(&mut self) -> Result<RunSession> {
        let (entry, fwd_entry) = if self.cfg.mode.is_rust_engine() {
            (None, None)
        } else {
            let reg = self.registry.as_ref().expect("artifact modes keep a registry");
            (
                Some(reg.get(&self.cfg.preset, self.entry_name())?),
                Some(reg.get(&self.cfg.preset, "fwd")?),
            )
        };
        let m = self.stack.m;
        let total = Timer::start();

        // observability (ISSUE 7): step tracing + JSONL streams. Both are
        // observation-only — a failed open degrades to a warning, and the
        // hot path only ever enqueues (the writer thread owns the disk).
        let tracing = self.cfg.trace.enabled;
        if tracing {
            crate::trace::set_enabled(true);
        }
        let recorder = tracing.then(|| {
            crate::trace::Recorder::new(&self.cfg.trace, crate::util::threadpool::bands())
        });
        let trace_writer = tracing
            .then(|| {
                let path = self.metrics.dir().join("trace.jsonl");
                match crate::trace::StreamWriter::create(&path, self.cfg.trace.buffer) {
                    Ok(w) => Some(w),
                    Err(e) => {
                        log::warn!("trace stream disabled: {e}");
                        None
                    }
                }
            })
            .flatten();
        // telemetry reports stream to the same run dir; the old periodic
        // `telemetry-NNNNNN.json` snapshot files are replaced by one
        // appended line per report interval (the final `telemetry.json`
        // snapshot below is unchanged)
        let telemetry_writer = (self.monitor.is_some() && self.cfg.telemetry.every > 0)
            .then(|| {
                let path = self.metrics.dir().join("telemetry.jsonl");
                match crate::trace::StreamWriter::create(&path, self.cfg.trace.buffer) {
                    Ok(w) => Some(w),
                    Err(e) => {
                        log::warn!("telemetry stream disabled: {e}");
                        None
                    }
                }
            })
            .flatten();
        // saliency summary lines (`[audit]` runs): periodic when
        // audit.every > 0, always one final line — the stream exists
        // whenever the tap does
        let saliency_writer = self
            .saliency
            .is_some()
            .then(|| {
                let path = self.metrics.dir().join("saliency.jsonl");
                match crate::trace::StreamWriter::create(&path, self.cfg.trace.buffer) {
                    Ok(w) => Some(w),
                    Err(e) => {
                        log::warn!("saliency stream disabled: {e}");
                        None
                    }
                }
            })
            .flatten();
        // checkpoint I/O off the hot path (ISSUE 9): the step loop
        // renders checkpoint bytes inline (memory-bound) and enqueues
        // them; the blob-writer thread owns the temp-write + rename.
        // Cap 2 — at most one in flight and one queued; on a stalled
        // disk newer snapshots drop (counted) and the previous
        // checkpoint on disk stays valid.
        let ckpt_writer = (self.cfg.checkpoint_every > 0).then(|| BlobWriter::spawn(2));

        // gather-prefetch pipeline (selection inline, gather overlapped)
        let depth = self.cfg.prefetch_depth;
        let (sel_tx, prefetcher) = if depth > 0 {
            let (tx, rx) = bounded(depth);
            let pf = Prefetcher::spawn_gather(self.train.clone(), rx, depth);
            (Some(tx), Some(pf))
        } else {
            (None, None)
        };

        // prime the pipeline with the first selection
        let first_sel = self.sampler.sample(m, &mut self.rng);
        let pending: Option<PreparedBatch> = match (&sel_tx, &prefetcher) {
            (Some(tx), Some(pf)) => {
                tx.send((self.step, first_sel))
                    .map_err(|_| anyhow!("prefetcher died"))?;
                Some(pf.recv().ok_or_else(|| anyhow!("prefetcher closed"))?)
            }
            _ => Some(prepare(&self.train, &first_sel, self.step)),
        };

        Ok(RunSession {
            entry,
            fwd_entry,
            total,
            tracing,
            recorder,
            trace_writer,
            telemetry_writer,
            saliency_writer,
            ckpt_writer,
            sel_tx,
            prefetcher,
            pending,
            curve: Vec::with_capacity(self.cfg.steps),
            end_step: self.step + self.cfg.steps,
            stopped: false,
        })
    }

    /// Execute ONE step of an open session; returns false once the
    /// session is exhausted (call [`Trainer::finish_session`] next).
    ///
    /// `stop = true` requests a clean early exit: the already-selected
    /// pending batch still executes (its RNG draw is consumed), but no
    /// lookahead selection is drawn — the RNG then sits at exactly the
    /// state an uninterrupted run reaches the same boundary with, which
    /// is what makes a shutdown checkpoint resume bitwise on noise-free
    /// runs (`tests/serve.rs` proves it). After a stop the session
    /// reports [`RunSession::stopped`] and refuses further steps.
    pub fn step_session(&mut self, s: &mut RunSession, stop: bool) -> Result<bool> {
        if s.stopped || self.step >= s.end_step {
            return Ok(false);
        }
        let m = self.stack.m;
        let end_step = if stop { self.step + 1 } else { s.end_step };
        let batch = s.pending.take().expect("pipeline always primed");
        debug_assert_eq!(batch.step, self.step);

        // dispatch the NEXT selection before executing this step so the
        // gather overlaps execution (norms are 1 step stale — the
        // staleness the importance sampler's EMA is built for)
        if self.step + 1 < end_step {
            let tsel = Timer::start();
            let sel = self.sampler.sample(m, &mut self.rng);
            match (&s.sel_tx, &s.prefetcher) {
                (Some(tx), Some(_)) => {
                    tx.send((self.step + 1, sel))
                        .map_err(|_| anyhow!("prefetcher died"))?;
                }
                _ => {
                    let _sp = crate::trace::span(crate::trace::Phase::DataLoad);
                    s.pending = Some(prepare(&self.train, &sel, self.step + 1));
                }
            }
            if let Some(p) = &mut self.profile {
                p.sample_gather += tsel.secs();
            }
        }

        let lr = self.cfg.schedule.at(self.step);
        let t = Timer::start();
        let rec = {
            let _sp = crate::trace::span(crate::trace::Phase::Step);
            self.execute_step(s.entry.as_ref(), &batch, lr)?
        };
        let step_ms = t.millis();
        s.curve.push((self.step, rec.loss));
        self.metrics.record(&StepRecord { step_ms, ..rec });

        if let Some(rec_tr) = s.recorder.as_mut() {
            rec_tr.end_step(self.step as u64, (step_ms * 1e6) as u64);
            let every = self.cfg.trace.every;
            if every > 0 && self.step > 0 && self.step % every == 0 {
                if let Some(w) = &s.trace_writer {
                    let _sp = crate::trace::span(crate::trace::Phase::Report);
                    let line = rec_tr.record(self.step as u64, w.reports_dropped());
                    w.enqueue(line.to_string());
                }
            }
        }

        if let Some(mon) = &self.monitor {
            let every = self.cfg.telemetry.every;
            if every > 0 && self.step > 0 && self.step % every == 0 {
                if let Some(w) = &s.telemetry_writer {
                    let _sp = crate::trace::span(crate::trace::Phase::Report);
                    w.enqueue(mon.report_with(self.clip.as_ref()).to_string());
                }
            }
        }

        if let Some(sal) = &self.saliency {
            let every = self.cfg.audit.every;
            if every > 0 && self.step > 0 && self.step % every == 0 {
                if let Some(w) = &s.saliency_writer {
                    let _sp = crate::trace::span(crate::trace::Phase::Report);
                    w.enqueue(sal.render_line(self.step).to_string());
                }
            }
        }

        if self.cfg.eval_every > 0
            && self.step > 0
            && self.step % self.cfg.eval_every == 0
        {
            let (el, ea) = self.evaluate(s.fwd_entry.as_ref())?;
            self.metrics.record_eval(self.step, el, ea);
        }
        if self.cfg.checkpoint_every > 0
            && self.step > 0
            && self.step % self.cfg.checkpoint_every == 0
        {
            let _sp = crate::trace::span(crate::trace::Phase::Checkpoint);
            match &s.ckpt_writer {
                Some(w) => self.enqueue_checkpoint(w)?,
                None => {
                    self.save_checkpoint()?;
                }
            }
        }

        self.step += 1;
        if self.cfg.prefetch_depth > 0 && self.step < end_step {
            let _sp = crate::trace::span(crate::trace::Phase::DataLoad);
            s.pending = Some(
                s.prefetcher
                    .as_ref()
                    .unwrap()
                    .recv()
                    .ok_or_else(|| anyhow!("prefetcher closed early"))?,
            );
        }
        if stop {
            s.stopped = true;
        }
        Ok(!s.stopped && self.step < s.end_step)
    }

    /// Close a session: shut the prefetch pipeline down, emit the final
    /// stream lines, drain every writer thread (the ONLY place a run
    /// waits on the disk — after its last step, never during one), dump
    /// saliency maps, run the final evaluation and assemble the
    /// [`RunSummary`]. Safe to call after an early stop: the summary
    /// then covers the steps that actually executed.
    pub fn finish_session(&mut self, mut s: RunSession) -> Result<RunSummary> {
        drop(s.sel_tx.take());
        drop(s.prefetcher.take());

        // close the streams: one final line each, then drain the writer
        // threads (the only place training waits on the disk — after the
        // last step, not during one)
        if let (Some(rec_tr), Some(w)) = (s.recorder.as_mut(), &s.trace_writer) {
            let last = self.step.saturating_sub(1) as u64;
            w.enqueue(rec_tr.record(last, w.reports_dropped()).to_string());
        }
        if let (Some(mon), Some(w)) = (&self.monitor, &s.telemetry_writer) {
            w.enqueue(mon.report_with(self.clip.as_ref()).to_string());
        }
        if let (Some(sal), Some(w)) = (&self.saliency, &s.saliency_writer) {
            let last = self.step.saturating_sub(1);
            w.enqueue(sal.render_line(last).to_string());
        }
        if let Some(w) = s.trace_writer.take() {
            let dropped = w.finish();
            if dropped > 0 {
                log::warn!("trace stream: {dropped} lines dropped (writer backpressure)");
            }
            log::info!("trace stream: {}", self.metrics.dir().join("trace.jsonl").display());
        }
        if let Some(w) = s.telemetry_writer.take() {
            let dropped = w.finish();
            if dropped > 0 {
                log::warn!(
                    "telemetry stream: {dropped} lines dropped (writer backpressure)"
                );
            }
        }
        if let Some(w) = s.saliency_writer.take() {
            let dropped = w.finish();
            if dropped > 0 {
                log::warn!(
                    "saliency stream: {dropped} lines dropped (writer backpressure)"
                );
            }
            log::info!(
                "saliency stream: {}",
                self.metrics.dir().join("saliency.jsonl").display()
            );
        }
        if let Some(w) = s.ckpt_writer.take() {
            let lost = w.finish();
            if lost > 0 {
                log::warn!(
                    "checkpoint writer: {lost} checkpoint(s) dropped or failed \
                     (the last durable checkpoint on disk is still valid)"
                );
            }
        }
        // dump the tracked maps (observation-only: a failed dump must not
        // fail the run) and remember the paths for `pegrad audit`
        if let Some(sal) = &self.saliency {
            match sal.write_maps(self.metrics.dir()) {
                Ok(paths) => {
                    log::info!(
                        "saliency maps: {} files under {}",
                        paths.len(),
                        self.metrics.dir().join("saliency").display()
                    );
                    self.saliency_maps = paths;
                }
                Err(e) => log::warn!("saliency map dump failed: {e}"),
            }
        }
        if s.tracing {
            crate::trace::set_enabled(false);
        }

        self.sync_params_to_host()?;
        let (eval_loss, eval_acc) = self.evaluate(s.fwd_entry.as_ref())?;
        self.metrics.record_eval(self.step, eval_loss, eval_acc);
        log::info!(
            "run '{}' done: {} steps in {:.1}s ({:.1} ms/step)",
            self.cfg.run_name,
            s.curve.len(),
            s.total.secs(),
            self.metrics.time_stats.mean()
        );
        if let Some(p) = &self.profile {
            log::info!("PEGRAD_PROFILE {}", p.report());
        }
        if let Some(ctrl) = &self.clip {
            log::info!(
                "adaptive clip: C {:.4} -> {:.4} tracking p{:.0} (sketch estimate {:.4})",
                ctrl.init_bound(),
                ctrl.bound(),
                ctrl.config().quantile * 100.0,
                ctrl.quantile_estimate().unwrap_or(f64::NAN)
            );
        }
        // telemetry is observation-only: a failed report write must not
        // turn a completed training run into an error
        let telemetry_path = self.monitor.as_ref().and_then(|mon| {
            let path = self.metrics.dir().join("telemetry.json");
            match mon.write_report_with(&path, self.clip.as_ref()) {
                Ok(()) => {
                    log::info!("telemetry report: {}", path.display());
                    Some(path)
                }
                Err(e) => {
                    log::warn!("telemetry report failed: {e}");
                    None
                }
            }
        });
        Ok(RunSummary {
            steps: s.curve.len(),
            final_loss: s.curve.last().map(|&(_, l)| l).unwrap_or(f32::NAN),
            eval_loss: Some(eval_loss),
            eval_accuracy: eval_acc,
            mean_step_ms: self.metrics.time_stats.mean(),
            curve: s.curve,
            epsilon: self
                .accountant
                .as_ref()
                .zip(self.cfg.privacy.as_ref())
                .map(|(a, p)| a.epsilon(p.delta)),
            telemetry_path,
        })
    }

    /// One fused-engine step: engine forward+backward (with the sampler's
    /// unbiased per-example weights folded into the Mean-mode rescale, and
    /// the telemetry tap attached when configured), optional DP noise,
    /// optimizer update, sampler feedback. No artifacts, no device I/O.
    fn execute_step_rust(&mut self, batch: &PreparedBatch, lr: f32) -> Result<StepRecord> {
        // adaptive bound (ISSUE 5): the controller's C — fed by the tap
        // stream of every PREVIOUS step — replaces the fixed constant in
        // the §6 coefficient vector; under rust_pegrad it only observes
        let adaptive_c = self.clip.as_ref().map(|c| c.bound());
        let mode = match self.cfg.mode {
            RunMode::RustPegrad => EngineMode::Mean,
            RunMode::RustClipped => EngineMode::Clip {
                c: adaptive_c
                    .unwrap_or_else(|| self.cfg.privacy.as_ref().expect("validated").clip_c),
                mean: true,
            },
            RunMode::RustNormalized => EngineMode::Normalize {
                target: adaptive_c.unwrap_or(self.cfg.normalize_target),
            },
            _ => unreachable!("execute_step_rust called for an artifact mode"),
        };
        // IS reweighting (§1): w_j = 1/(N p_j)/m, already batch-mean
        // normalized by the sampler — uniform sampling yields exactly 1/m,
        // so the engine's plain mean is the special case
        let weights = matches!(self.cfg.mode, RunMode::RustPegrad)
            .then_some(batch.weights.as_slice());
        let engine = self.engine.as_mut().expect("rust modes own an engine");
        // one tap slot on the engine: monitor, controller and/or the
        // saliency tap, tee'd as needed (TeeTap nests, so three sinks are
        // two tees) — each sink sees exactly the stream it would alone
        let mut tee_inner;
        let mut tee;
        let tap: Option<&mut dyn LayerTap> = match (
            self.monitor.as_mut(),
            self.clip.as_mut(),
            self.saliency.as_mut(),
        ) {
            (Some(m), Some(c), Some(s)) => {
                tee_inner = TeeTap {
                    first: c,
                    second: s,
                };
                tee = TeeTap {
                    first: m,
                    second: &mut tee_inner,
                };
                Some(&mut tee)
            }
            (Some(m), Some(c), None) => {
                tee = TeeTap {
                    first: m,
                    second: c,
                };
                Some(&mut tee)
            }
            (Some(m), None, Some(s)) => {
                tee = TeeTap {
                    first: m,
                    second: s,
                };
                Some(&mut tee)
            }
            (None, Some(c), Some(s)) => {
                tee = TeeTap {
                    first: c,
                    second: s,
                };
                Some(&mut tee)
            }
            (Some(m), None, None) => Some(m),
            (None, Some(c), None) => Some(c),
            (None, None, Some(s)) => Some(s),
            (None, None, None) => None,
        };
        let stats =
            engine.step_streamed(&self.params, &batch.x, &batch.y, mode, weights, tap);
        // complete the telemetry step BEFORE DP noise: the GNS big-batch
        // moment should see the gradient the math defines (ḡ in mean mode,
        // the clipped mean in clipped mode), not the privacy noise
        if let Some(mon) = self.monitor.as_mut() {
            mon.end_step(
                &batch.indices,
                self.engine
                    .as_ref()
                    .expect("validated: rust-engine modes own an engine")
                    .grads(),
            );
        }
        // then fold the staged maps into the tracked flagged set — the
        // detector's counts are current as of the end_step above
        if let (Some(sal), Some(mon)) = (self.saliency.as_mut(), self.monitor.as_ref()) {
            sal.end_step(&batch.indices, mon.outliers());
        }

        if let (RunMode::RustClipped, Some(p)) = (self.cfg.mode, self.cfg.privacy.clone()) {
            if p.noise_sigma > 0.0 {
                // DP-SGD gaussian noise on the MEAN clipped gradient:
                // sigma * C / m per coordinate, from the run RNG. Under
                // adaptive clipping the per-step sensitivity is the
                // CURRENT bound, so the noise scales with it (Andrew et
                // al. 2021), not with the initial clip_c.
                let c_used = adaptive_c.unwrap_or(p.clip_c);
                let scale = p.noise_sigma * c_used / self.stack.m as f32;
                let rng = &mut self.rng;
                let engine = self
                    .engine
                    .as_mut()
                    .expect("validated: rust-engine modes own an engine");
                for g in engine.grads_mut() {
                    for v in g.data_mut() {
                        *v += scale * rng.next_normal();
                    }
                }
            }
            if let Some(acc) = &mut self.accountant {
                acc.observe_steps(1);
            }
        }

        self.optimizer.step(
            &mut self.params,
            self.engine
                .as_ref()
                .expect("validated: rust-engine modes own an engine")
                .grads(),
            lr,
        );
        // norm feedback (§1 loop): the engine computed them in-pass
        {
            let engine = self
                .engine
                .as_ref()
                .expect("validated: rust-engine modes own an engine");
            self.sampler.observe(&batch.indices, engine.norms());
        }
        let norms: Vec<f32> = self
            .engine
            .as_ref()
            .expect("validated: rust-engine modes own an engine")
            .norms()
            .to_vec();
        Ok(self.record(stats.mean_loss, Some(&norms), stats.clip_frac, lr))
    }

    /// Execute one step in the configured mode; returns the step record
    /// (with step_ms left 0 — the caller times the whole thing).
    fn execute_step(
        &mut self,
        entry: Option<&std::rc::Rc<Entry>>,
        batch: &PreparedBatch,
        lr: f32,
    ) -> Result<StepRecord> {
        if self.cfg.mode.is_rust_engine() {
            return self.execute_step_rust(batch, lr);
        }
        let entry = entry.expect("artifact modes pass an entry");
        let n = self.stack.n_params();
        match self.cfg.mode {
            RunMode::RustPegrad | RunMode::RustClipped | RunMode::RustNormalized => {
                unreachable!("handled above")
            }
            RunMode::RustOptim => {
                // host path: grads come back, rust optimizer applies them
                let mut args: Vec<Arg> = self.params.iter().map(Arg::from).collect();
                args.push(Arg::from(&batch.x));
                args.push(Arg::from(&batch.y));
                let out = entry.call(&args)?;
                let loss = out[0].item();
                let grads = &out[1..1 + n];
                // fold IS weights: grads_pegrad returns the uniform mean, so
                // re-weight on host when the sampler is non-uniform
                // (difference vs uniform is the weights' deviation from 1/m)
                self.optimizer.step(&mut self.params, grads, lr);
                let s_total = out[1 + n].data().to_vec();
                let norms: Vec<f32> = s_total.iter().map(|s| s.sqrt()).collect();
                self.sampler.observe(&batch.indices, &norms);
                Ok(self.record(loss, Some(&norms), None, lr))
            }
            RunMode::Vanilla => {
                self.ensure_dev_params()?;
                let (x, y, lr_buf) = self.upload_batch(batch, lr)?;
                let mut refs: Vec<&xla::PjRtBuffer> =
                    self.dev_params.as_ref().unwrap().iter().collect();
                refs.push(&x);
                refs.push(&y);
                refs.push(&lr_buf);
                let out = entry.call_device(&refs)?;
                let loss = fetch_f32(&out[n])?.item();
                self.dev_params = Some(out.into_iter().take(n).collect());
                Ok(self.record(loss, None, None, lr))
            }
            RunMode::Pegrad => {
                self.ensure_dev_params()?;
                let t_up = Timer::start();
                let (x, y, lr_buf) = self.upload_batch(batch, lr)?;
                let c = crate::runtime::client::global();
                let w = c
                    .buffer_from_host_buffer(&batch.weights, &[batch.weights.len()], None)
                    .map_err(|e| anyhow!("weights upload: {e}"))?;
                let upload_s = t_up.secs();
                let mut refs: Vec<&xla::PjRtBuffer> =
                    self.dev_params.as_ref().unwrap().iter().collect();
                refs.push(&x);
                refs.push(&y);
                refs.push(&lr_buf);
                refs.push(&w);
                let t_ex = Timer::start();
                let out = entry.call_device(&refs)?;
                let execute_s = t_ex.secs();
                // outputs: params' (n), mean_loss, s_total, s_layers
                let t_f = Timer::start();
                let loss = fetch_f32(&out[n])?.item();
                let s_total = fetch_f32(&out[n + 1])?;
                let fetch_s = t_f.secs();
                let norms: Vec<f32> = s_total.data().iter().map(|s| s.sqrt()).collect();
                self.sampler.observe(&batch.indices, &norms);
                self.dev_params = Some(out.into_iter().take(n).collect());
                if let Some(p) = &mut self.profile {
                    p.upload += upload_s;
                    p.execute += execute_s;
                    p.fetch += fetch_s;
                    p.steps += 1;
                }
                Ok(self.record(loss, Some(&norms), None, lr))
            }
            RunMode::Clipped => {
                self.ensure_dev_params()?;
                let p = self.cfg.privacy.as_ref().expect("validated");
                let (x, y, lr_buf) = self.upload_batch(batch, lr)?;
                let c = crate::runtime::client::global();
                let mk = |v: f32| {
                    c.buffer_from_host_buffer(&[v], &[1], None)
                        .map_err(|e| anyhow!("scalar upload: {e}"))
                };
                let cc = mk(p.clip_c)?;
                let sg = mk(p.noise_sigma)?;
                let seed_v = [self.rng.next_u64() as i32];
                let seed = c
                    .buffer_from_host_buffer(&seed_v, &[1], None)
                    .map_err(|e| anyhow!("seed upload: {e}"))?;
                let mut refs: Vec<&xla::PjRtBuffer> =
                    self.dev_params.as_ref().unwrap().iter().collect();
                refs.push(&x);
                refs.push(&y);
                refs.push(&lr_buf);
                refs.push(&cc);
                refs.push(&sg);
                refs.push(&seed);
                let out = entry.call_device(&refs)?;
                // outputs: params' (n), mean_loss, s_total, clip_frac
                let loss = fetch_f32(&out[n])?.item();
                let s_total = fetch_f32(&out[n + 1])?;
                let clip_frac = fetch_f32(&out[n + 2])?.item();
                let norms: Vec<f32> = s_total.data().iter().map(|s| s.sqrt()).collect();
                self.sampler.observe(&batch.indices, &norms);
                if let Some(acc) = &mut self.accountant {
                    acc.observe_steps(1);
                }
                self.dev_params = Some(out.into_iter().take(n).collect());
                Ok(self.record(loss, Some(&norms), Some(clip_frac), lr))
            }
        }
    }

    fn upload_batch(
        &self,
        batch: &PreparedBatch,
        lr: f32,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)> {
        let c = crate::runtime::client::global();
        let x = c
            .buffer_from_host_buffer(batch.x.data(), batch.x.dims(), None)
            .map_err(|e| anyhow!("x upload: {e}"))?;
        let y = match &batch.y {
            Targets::Classes(v) => c
                .buffer_from_host_buffer(&v[..], &[v.len()], None)
                .map_err(|e| anyhow!("y upload: {e}"))?,
            Targets::Dense(t) => c
                .buffer_from_host_buffer(t.data(), t.dims(), None)
                .map_err(|e| anyhow!("y upload: {e}"))?,
        };
        let lr_buf = c
            .buffer_from_host_buffer(&[lr], &[1], None)
            .map_err(|e| anyhow!("lr upload: {e}"))?;
        Ok((x, y, lr_buf))
    }

    fn record(
        &self,
        loss: f32,
        norms: Option<&[f32]>,
        clip_frac: Option<f32>,
        lr: f32,
    ) -> StepRecord {
        let (mean_norm, max_norm) = match norms {
            Some(v) if !v.is_empty() => (
                Some(v.iter().sum::<f32>() / v.len() as f32),
                Some(v.iter().cloned().fold(f32::MIN, f32::max)),
            ),
            _ => (None, None),
        };
        StepRecord {
            step: self.step,
            loss,
            lr,
            mean_norm,
            max_norm,
            clip_frac,
            epsilon: self
                .accountant
                .as_ref()
                .zip(self.cfg.privacy.as_ref())
                .map(|(a, p)| a.epsilon(p.delta)),
            step_ms: 0.0,
        }
    }

    /// Evaluate mean loss (and accuracy for CE) on the eval set, in
    /// batches of exactly m (artifact shapes are static; the rust-engine
    /// path keeps the same batching for comparable numbers).
    fn evaluate(&mut self, fwd: Option<&std::rc::Rc<Entry>>) -> Result<(f32, Option<f32>)> {
        self.sync_params_to_host()?;
        let m = self.stack.m;
        let out_len = self.stack.out_len();
        let n_batches = self.eval.len() / m;
        if n_batches == 0 {
            return Ok((f32::NAN, None));
        }
        let mut loss_sum = 0f64;
        let mut hits = 0usize;
        let mut seen = 0usize;
        for b in 0..n_batches {
            let idx: Vec<usize> = (b * m..(b + 1) * m).collect();
            let (x, y) = self.eval.batch(&idx);
            let is_classes = matches!(y, Targets::Classes(_));
            // predictions only for classification — regression evals skip
            // the argmax scan entirely
            let pred: Option<Vec<usize>>;
            if self.cfg.mode.is_rust_engine() {
                // fused-engine forward — works for every stack (dense or
                // conv) and reuses the step workspace, zero allocations
                let engine = self.engine.as_mut().expect("rust modes own an engine");
                loss_sum += engine.forward_only(&self.params, &x, &y) as f64;
                pred = is_classes
                    .then(|| ops::row_argmax_rows(engine.logits(), m, out_len));
            } else {
                let fwd = fwd.expect("artifact modes pass a fwd entry");
                let mut args: Vec<Arg> = self.params.iter().map(Arg::from).collect();
                args.push(Arg::from(&x));
                args.push(Arg::from(&y));
                let mut out = fwd.call(&args)?;
                loss_sum += out[0].item() as f64;
                pred = is_classes.then(|| ops::row_argmax(&out.swap_remove(2)));
            }
            if let (Targets::Classes(cls), Some(pred)) = (&y, pred) {
                hits += pred
                    .iter()
                    .zip(cls)
                    .filter(|(p, c)| **p == **c as usize)
                    .count();
                seen += m;
            }
        }
        let acc = (seen > 0).then(|| hits as f32 / seen as f32);
        Ok(((loss_sum / n_batches as f64) as f32, acc))
    }

    /// Save a checkpoint of the current state SYNCHRONOUSLY to
    /// `<run_dir>/ckpt-<step>.bin` and return its path. Periodic
    /// in-loop checkpoints go through [`Trainer::step_session`]'s
    /// asynchronous blob-writer path instead; this is the
    /// end-of-run/shutdown form, where waiting on the disk is fine.
    pub fn save_checkpoint(&mut self) -> Result<std::path::PathBuf> {
        let (path, ck) = self.render_checkpoint()?;
        ck.save(&path).context("saving checkpoint")?;
        log::info!("checkpoint saved: {}", path.display());
        Ok(path)
    }

    /// Render the current checkpoint (params, optimizer, RNG, clip +
    /// flag state) and its target path — the serialization half shared
    /// by the sync and async save paths.
    fn render_checkpoint(&mut self) -> Result<(std::path::PathBuf, Checkpoint)> {
        self.sync_params_to_host()?;
        let opt_state: Vec<Tensor> = self.optimizer.state().into_iter().cloned().collect();
        let ck = Checkpoint::new(
            self.step as u64,
            &self.rng,
            self.params.clone(),
            opt_state,
        )
        .with_clip(self.clip.as_ref().map(|c| c.snapshot()))
        .with_flags(self.monitor.as_ref().map(|m| m.outliers().flag_state()));
        let path = self.metrics.dir().join(format!("ckpt-{:06}.bin", self.step));
        Ok((path, ck))
    }

    /// Render the current checkpoint and hand its bytes to the
    /// session's blob-writer thread: the step loop pays only the
    /// (memory-bound) serialization, never the disk.
    fn enqueue_checkpoint(&mut self, w: &BlobWriter) -> Result<()> {
        let (path, ck) = self.render_checkpoint()?;
        if w.enqueue(path, ck.to_bytes()) {
            log::info!("checkpoint queued: step {}", self.step);
        } else {
            log::warn!(
                "checkpoint at step {} dropped (blob-writer backpressure)",
                self.step
            );
        }
        Ok(())
    }

    /// The next step index this trainer will execute (total steps
    /// completed across restores — the serve scheduler's progress
    /// counter).
    pub fn current_step(&self) -> usize {
        self.step
    }

    /// Current host-side parameters (synced from device first).
    pub fn params(&mut self) -> Result<&[Tensor]> {
        self.sync_params_to_host()?;
        Ok(&self.params)
    }

    /// Reference-model view of the current parameters (for analysis).
    /// Dense models only — conv stacks run exclusively on the fused
    /// engine (use [`Trainer::params`] + `FusedEngine::from_stack`).
    pub fn reference_model(&mut self) -> Result<Mlp> {
        self.sync_params_to_host()?;
        let spec = self.dense_spec.clone().ok_or_else(|| {
            anyhow!("reference_model needs a dense model; this run uses a layer stack")
        })?;
        Ok(Mlp::new(spec, self.params.clone()))
    }
}

/// Build (train, eval) datasets per config. Eval sizes are multiples of m
/// (artifact batch shapes are static).
fn build_datasets(cfg: &Config, stack: &StackSpec, rng: &mut Rng) -> Result<(Dataset, Dataset)> {
    // loss/target compatibility: CE needs class targets, MSE dense ones
    match (stack.loss, cfg.data) {
        (crate::nn::Loss::SoftmaxCe, DataKind::Regression) => {
            bail!("regression data produces dense targets but the preset uses softmax_ce")
        }
        (crate::nn::Loss::Mse, DataKind::Synth | DataKind::Digits | DataKind::Seq) => {
            bail!("classification data produces class targets but the preset uses mse; use data.kind=\"regression\"")
        }
        _ => {}
    }
    let eval_n = (4 * stack.m).max(64) / stack.m * stack.m;
    let mk = |n: usize, seed: u64| -> Result<Dataset> {
        Ok(match cfg.data {
            DataKind::Synth => {
                synth::generate(&synth::SynthConfig {
                    n,
                    dim: stack.in_len(),
                    n_classes: stack.out_len(),
                    imbalance: cfg.imbalance,
                    label_noise: cfg.label_noise,
                    seed,
                    ..Default::default()
                })
                .0
            }
            DataKind::Digits => {
                // a conv stack's single-channel HxW input is the same
                // flat layout the dense models consume
                let side = (stack.in_len() as f64).sqrt() as usize;
                if side * side != stack.in_len() || side < 9 {
                    bail!(
                        "digits data needs a square (single-channel) input dim >= 81, got {}",
                        stack.in_len()
                    );
                }
                digits::generate(&digits::DigitsConfig {
                    n,
                    side,
                    seed,
                    ..Default::default()
                })
            }
            DataKind::Regression => regression::generate(&regression::RegressionConfig {
                n,
                dim: stack.in_len(),
                out_dim: stack.out_len(),
                seed,
                ..Default::default()
            }),
            DataKind::Seq => {
                // token count and vocabulary come from the stack's leading
                // embedding layer (embedding-first is validated upstream)
                let Some(&crate::nn::layers::LayerSpec::Embedding { vocab, toks, .. }) =
                    stack.layers.first()
                else {
                    bail!("seq data requires a model.stack starting with 'embed V d'")
                };
                seq::generate(&seq::SeqConfig {
                    n,
                    toks,
                    vocab,
                    n_classes: stack.out_len(),
                    label_noise: cfg.label_noise,
                    seed,
                    ..Default::default()
                })
                .0
            }
        })
    };
    // One generation, then split: train and eval must come from the SAME
    // underlying distribution (same mixture centers / teacher / glyph
    // statistics), which a second seed would not give.
    let base_seed = rng.next_u64();
    let full = mk(cfg.data_n + eval_n, base_seed)?;
    Ok(full.split_at(cfg.data_n))
}

/// The `telemetry.norm_layers_only` tap mask: one entry per WEIGHTED
/// layer (the engine's `wi` indexing), true exactly for LayerNorm layers
/// — the per-example-gradient subset Gray et al. 2024 show predicts GNS
/// on its own.
fn norm_layer_mask(stack: &StackSpec) -> Vec<bool> {
    stack
        .layers
        .iter()
        .filter(|l| l.weight_shape().is_some())
        .map(|l| matches!(l, crate::nn::layers::LayerSpec::LayerNorm { .. }))
        .collect()
}
