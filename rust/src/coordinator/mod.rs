//! The L3 training coordinator: the loop that ties sampler → runtime →
//! optimizer → norm feedback together, with metrics and checkpoints.
//! (System map: `docs/architecture.md`.)
//!
//! Threading model (PJRT wrappers are not `Send` — see
//! [`crate::runtime::client`]): all artifact execution happens on the
//! thread that owns the [`Trainer`]; the batch GATHER is overlapped via
//! the bounded-channel prefetcher in [`crate::data::loader`]. Sampling
//! itself stays inline because it feeds back on executed norms.
//! A run's in-flight resources (streams, prefetcher, checkpoint
//! writer) live in a per-run [`trainer::RunSession`] arena, so many
//! trainers can step concurrently on their own threads — the `serve`
//! scheduler's substrate.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use metrics::{MetricsLogger, StepRecord};
pub use trainer::{RunSession, RunSummary, Trainer};
