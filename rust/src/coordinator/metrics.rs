//! Run metrics: JSONL event log + CSV table + console summaries.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::stats::Welford;
use crate::util::Json;

/// One training step's measurements.
#[derive(Debug, Clone)]
pub struct StepRecord {
    /// Step index (0-based).
    pub step: usize,
    /// Minibatch training loss.
    pub loss: f32,
    /// Learning rate used for this step.
    pub lr: f32,
    /// mean per-example gradient norm (sqrt of s), if computed this step.
    pub mean_norm: Option<f32>,
    /// Largest per-example gradient norm in the batch, if computed.
    pub max_norm: Option<f32>,
    /// Fraction of examples clipped this step, if clipping ran.
    pub clip_frac: Option<f32>,
    /// Cumulative privacy spend after this step, if DP accounting is on.
    pub epsilon: Option<f64>,
    /// Wall-clock step latency in milliseconds.
    pub step_ms: f64,
}

impl StepRecord {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("step", Json::num(self.step as f64)),
            ("loss", Json::num(self.loss as f64)),
            ("lr", Json::num(self.lr as f64)),
            ("step_ms", Json::num(self.step_ms)),
        ];
        if let Some(v) = self.mean_norm {
            pairs.push(("mean_norm", Json::num(v as f64)));
        }
        if let Some(v) = self.max_norm {
            pairs.push(("max_norm", Json::num(v as f64)));
        }
        if let Some(v) = self.clip_frac {
            pairs.push(("clip_frac", Json::num(v as f64)));
        }
        if let Some(v) = self.epsilon {
            pairs.push(("epsilon", Json::num(v)));
        }
        Json::obj(pairs)
    }
}

/// Writes metrics.jsonl + metrics.csv under `<out_dir>/<run_name>/`.
pub struct MetricsLogger {
    dir: PathBuf,
    jsonl: Option<fs::File>,
    csv: Option<fs::File>,
    /// Running loss statistics over every recorded step.
    pub loss_stats: Welford,
    /// Running step-latency statistics (ms) over every recorded step.
    pub time_stats: Welford,
    console_every: usize,
}

impl MetricsLogger {
    /// Create `<out_dir>/<run_name>/` and open `metrics.jsonl` + `metrics.csv`.
    pub fn new(out_dir: &str, run_name: &str, console_every: usize) -> Result<MetricsLogger> {
        let dir = Path::new(out_dir).join(run_name);
        fs::create_dir_all(&dir)?;
        let jsonl = fs::File::create(dir.join("metrics.jsonl"))?;
        let mut csv = fs::File::create(dir.join("metrics.csv"))?;
        writeln!(
            csv,
            "step,loss,lr,mean_norm,max_norm,clip_frac,epsilon,step_ms"
        )?;
        Ok(MetricsLogger {
            dir,
            jsonl: Some(jsonl),
            csv: Some(csv),
            loss_stats: Welford::new(),
            time_stats: Welford::new(),
            console_every,
        })
    }

    /// A logger that keeps stats but writes no files (tests/benches).
    pub fn null() -> MetricsLogger {
        MetricsLogger {
            dir: PathBuf::new(),
            jsonl: None,
            csv: None,
            loss_stats: Welford::new(),
            time_stats: Welford::new(),
            console_every: 0,
        }
    }

    /// The run directory the metrics files live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Record one step: update stats, append the JSONL + CSV rows, and
    /// print a console line every `console_every` steps.
    pub fn record(&mut self, r: &StepRecord) {
        self.loss_stats.push(r.loss as f64);
        self.time_stats.push(r.step_ms);
        if let Some(f) = &mut self.jsonl {
            let _ = writeln!(f, "{}", r.to_json());
        }
        if let Some(f) = &mut self.csv {
            let opt = |v: Option<f32>| v.map(|x| x.to_string()).unwrap_or_default();
            let _ = writeln!(
                f,
                "{},{},{},{},{},{},{},{:.3}",
                r.step,
                r.loss,
                r.lr,
                opt(r.mean_norm),
                opt(r.max_norm),
                opt(r.clip_frac),
                r.epsilon.map(|e| e.to_string()).unwrap_or_default(),
                r.step_ms
            );
        }
        if self.console_every > 0 && r.step % self.console_every == 0 {
            log::info!(
                "step {:>5}  loss {:.4}  lr {:.2e}  {}{}{:.1}ms",
                r.step,
                r.loss,
                r.lr,
                r.mean_norm
                    .map(|n| format!("|g| {n:.3}  "))
                    .unwrap_or_default(),
                r.clip_frac
                    .map(|c| format!("clip {:.0}%  ", c * 100.0))
                    .unwrap_or_default(),
                r.step_ms
            );
        }
    }

    /// Log an eval point (separate stream in the jsonl).
    pub fn record_eval(&mut self, step: usize, loss: f32, accuracy: Option<f32>) {
        if let Some(f) = &mut self.jsonl {
            let mut pairs = vec![
                ("eval_step", Json::num(step as f64)),
                ("eval_loss", Json::num(loss as f64)),
            ];
            if let Some(a) = accuracy {
                pairs.push(("eval_accuracy", Json::num(a as f64)));
            }
            let _ = writeln!(f, "{}", Json::obj(pairs));
        }
        log::info!(
            "eval  step {:>5}  loss {:.4}{}",
            step,
            loss,
            accuracy
                .map(|a| format!("  acc {:.1}%", a * 100.0))
                .unwrap_or_default()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize) -> StepRecord {
        StepRecord {
            step,
            loss: 1.5,
            lr: 0.1,
            mean_norm: Some(2.0),
            max_norm: Some(5.0),
            clip_frac: None,
            epsilon: None,
            step_ms: 3.25,
        }
    }

    #[test]
    fn writes_jsonl_and_csv() {
        let tmp = std::env::temp_dir().join(format!("pegrad-metrics-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&tmp);
        let mut m =
            MetricsLogger::new(tmp.to_str().unwrap(), "t1", 0).unwrap();
        m.record(&rec(0));
        m.record(&rec(1));
        m.record_eval(1, 0.9, Some(0.5));
        drop(m);
        let jsonl = std::fs::read_to_string(tmp.join("t1/metrics.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 3);
        let first = Json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("loss").unwrap().as_f64().unwrap(), 1.5);
        let csv = std::fs::read_to_string(tmp.join("t1/metrics.csv")).unwrap();
        assert!(csv.starts_with("step,loss"));
        assert_eq!(csv.lines().count(), 3); // header + 2 steps
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn null_logger_accumulates_stats() {
        let mut m = MetricsLogger::null();
        for s in 0..10 {
            m.record(&rec(s));
        }
        assert_eq!(m.loss_stats.count(), 10);
        assert!((m.time_stats.mean() - 3.25).abs() < 1e-9);
    }
}
