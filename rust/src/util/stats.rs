//! Streaming and batch statistics for metrics and the bench harness.

/// Welford online mean/variance accumulator (numerically stable).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Observations seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 before any observation).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Batch summary with percentiles, used by the bench harness reports.
#[derive(Debug, Clone)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median (linear-interpolated).
    pub p50: f64,
    /// 95th percentile (linear-interpolated).
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Compute from raw samples. Not `const`-happy: sorts a copy.
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of needs >=1 sample");
        let mut s: Vec<f64> = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in &s {
            w.push(x);
        }
        Summary {
            n: s.len(),
            mean: w.mean(),
            std: w.std(),
            min: s[0],
            p50: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            max: *s.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponential moving average with bias correction, used by the
/// importance sampler's norm store and metric smoothing.
#[derive(Debug, Clone)]
pub struct Ema {
    lambda: f64,
    value: f64,
    weight: f64,
}

impl Ema {
    /// `lambda` in (0, 1]: weight on the NEW observation.
    pub fn new(lambda: f64) -> Self {
        assert!(lambda > 0.0 && lambda <= 1.0, "lambda must be in (0,1]");
        Ema {
            lambda,
            value: 0.0,
            weight: 0.0,
        }
    }

    /// Fold in one observation.
    pub fn push(&mut self, x: f64) {
        self.value = (1.0 - self.lambda) * self.value + self.lambda * x;
        self.weight = (1.0 - self.lambda) * self.weight + self.lambda;
    }

    /// Bias-corrected current estimate; `None` before any observation.
    pub fn get(&self) -> Option<f64> {
        if self.weight == 0.0 {
            None
        } else {
            Some(self.value / self.weight)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_two_pass() {
        let xs: Vec<f64> = (0..100).map(|i| ((i * 37) % 13) as f64).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-9);
        assert_eq!(w.count(), 100);
    }

    #[test]
    fn welford_extremes() {
        let mut w = Welford::new();
        w.push(5.0);
        assert_eq!(w.var(), 0.0);
        assert_eq!((w.min(), w.max()), (5.0, 5.0));
        w.push(-3.0);
        assert_eq!((w.min(), w.max()), (-3.0, 5.0));
    }

    #[test]
    fn percentiles() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&s, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&s, 0.0), 1.0);
        assert_eq!(percentile_sorted(&s, 100.0), 100.0);
        assert_eq!(percentile_sorted(&[7.0], 95.0), 7.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        Summary::of(&[]);
    }

    #[test]
    fn ema_bias_correction() {
        let mut e = Ema::new(0.1);
        assert!(e.get().is_none());
        e.push(10.0);
        // bias-corrected first observation is exactly itself
        assert!((e.get().unwrap() - 10.0).abs() < 1e-12);
        for _ in 0..500 {
            e.push(2.0);
        }
        assert!((e.get().unwrap() - 2.0).abs() < 1e-6);
    }

    #[test]
    fn ema_tracks_recent() {
        let mut a = Ema::new(0.5);
        let mut b = Ema::new(0.01);
        for x in [0.0, 0.0, 0.0, 10.0, 10.0] {
            a.push(x);
            b.push(x);
        }
        assert!(a.get().unwrap() > b.get().unwrap());
    }
}
