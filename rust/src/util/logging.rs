//! Leveled stderr logger implementing the `log` crate facade.
//!
//! Replaces `env_logger` (not vendored).  Level comes from `PEGRAD_LOG`
//! (error|warn|info|debug|trace), default `info`.  Output format:
//! `[  12.345s INFO  pegrad::coordinator] message`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

static START: Lazy<Instant> = Lazy::new(Instant::now);
static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, meta: &log::Metadata) -> bool {
        meta.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        eprintln!(
            "[{:9.3}s {:5} {}] {}",
            START.elapsed().as_secs_f64(),
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

/// Parse a level name; unknown names fall back to `info`.
pub fn parse_level(s: &str) -> log::LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => log::LevelFilter::Off,
        "error" => log::LevelFilter::Error,
        "warn" => log::LevelFilter::Warn,
        "debug" => log::LevelFilter::Debug,
        "trace" => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    }
}

/// Install the logger once; later calls are no-ops (tests may race).
pub fn init() {
    init_with(
        std::env::var("PEGRAD_LOG")
            .map(|v| parse_level(&v))
            .unwrap_or(log::LevelFilter::Info),
    );
}

/// Install the logger at an explicit level (benches/tests).
pub fn init_with(level: log::LevelFilter) {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    Lazy::force(&START);
    let logger = Box::leak(Box::new(StderrLogger { level }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(parse_level("error"), log::LevelFilter::Error);
        assert_eq!(parse_level("TRACE"), log::LevelFilter::Trace);
        assert_eq!(parse_level("bogus"), log::LevelFilter::Info);
        assert_eq!(parse_level("off"), log::LevelFilter::Off);
    }

    #[test]
    fn double_init_is_safe() {
        init_with(log::LevelFilter::Warn);
        init_with(log::LevelFilter::Trace); // no panic, no re-install
        log::warn!("logging smoke test");
    }
}
