//! Wall-clock timing helpers for metrics and the bench harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Time since start (or last reset).
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Return the elapsed time and restart from zero.
    pub fn reset(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let v = f();
    (v, t.secs())
}

/// Human-readable duration (for log lines and bench reports).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
        let e = t.reset();
        assert!(e.as_secs_f64() >= 0.004);
        assert!(t.secs() < 0.004);
    }

    #[test]
    fn time_returns_value() {
        let (v, s) = time(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(3e-9).ends_with("ns"));
        assert!(fmt_duration(3e-6).ends_with("µs"));
        assert!(fmt_duration(3e-3).ends_with("ms"));
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(300.0), "5.0min");
    }
}
