//! Mini property-testing framework (proptest is not in the vendored
//! registry — DESIGN.md §6).
//!
//! Seeded generators + N-case sweeps + shrink-by-halving on failure.
//! Usage:
//!
//! ```ignore
//! prop::check(100, |g| {
//!     let v = g.vec_f32(1..100, -10.0..10.0);
//!     let t = SumTree::from(&v.iter().map(|x| x.abs()).collect::<Vec<_>>());
//!     prop::assert_close(t.total(), v.iter().map(|x| x.abs()).sum(), 1e-4)
//! });
//! ```

use std::ops::Range;

use crate::tensor::rng::Rng;

/// Generator handed to each property case: a seeded RNG plus sampling
/// helpers. Records sizes so failures can shrink.
pub struct Gen {
    rng: Rng,
    /// Which property case this generator is for (0-based).
    pub case: u64,
    /// Shrink factor in (0, 1]; sizes are scaled down by it on retry.
    shrink: f64,
}

impl Gen {
    fn new(seed: u64, case: u64, shrink: f64) -> Self {
        Gen {
            rng: Rng::new(seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15))),
            case,
            shrink,
        }
    }

    /// Uniform `usize` in `r` (upper bound shrunk on retry).
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        let span = (r.end - r.start) as f64;
        let scaled = (span * self.shrink).max(1.0) as usize;
        r.start + (self.rng.next_u64() as usize) % scaled
    }

    /// Uniform `i64` in `r`.
    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        assert!(r.start < r.end);
        let span = (r.end - r.start) as u64;
        r.start + (self.rng.next_u64() % span) as i64
    }

    /// Uniform `f32` in `r`.
    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        r.start + self.rng.next_f32() * (r.end - r.start)
    }

    /// Uniform `f64` in `r`.
    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        r.start + self.rng.next_f64() * (r.end - r.start)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Standard-normal sample.
    pub fn normal(&mut self) -> f32 {
        self.rng.next_normal()
    }

    /// Vector of uniform values; length drawn from `len`.
    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Vector of standard-normal values; length drawn from `len`.
    pub fn vec_normal(&mut self, len: Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.normal()).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated inputs. On failure, retries the same
/// case seed with smaller size factors to report a (roughly) minimal
/// reproduction, then panics with the seed so it can be replayed.
pub fn check(cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let seed = match std::env::var("PEGRAD_PROP_SEED") {
        Ok(s) => s.parse().expect("PEGRAD_PROP_SEED must be u64"),
        Err(_) => 0xDEFA017,
    };
    for case in 0..cases {
        let mut g = Gen::new(seed, case, 1.0);
        if let Err(msg) = prop(&mut g) {
            // Shrink: same case seed, smaller size budget.
            let mut best = (1.0f64, msg);
            for &factor in &[0.5, 0.25, 0.125, 0.0625] {
                let mut g = Gen::new(seed, case, factor);
                if let Err(msg2) = prop(&mut g) {
                    best = (factor, msg2);
                }
            }
            panic!(
                "property failed (seed={seed}, case={case}, shrink={}): {}\n\
                 replay with PEGRAD_PROP_SEED={seed}",
                best.0, best.1
            );
        }
    }
}

/// Property-style assertion helpers (return Result so `check` can shrink).
pub fn require(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Relative closeness check (with an absolute escape hatch near zero).
pub fn assert_close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    // relative check with a small absolute escape hatch for
    // cancellation-prone values near zero (f32 accumulation order differs
    // between blocked/parallel and naive kernels)
    if (a - b).abs() / denom <= tol || (a - b).abs() <= tol * 1e-2 {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rel tol {tol})"))
    }
}

/// [`assert_close`] over two slices, reporting the first failing index.
pub fn assert_all_close(a: &[f32], b: &[f32], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert_close(x as f64, y as f64, tol)
            .map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(50, |g| {
            let v = g.vec_f32(0..20, -5.0..5.0);
            let s: f32 = v.iter().sum();
            let s2: f32 = v.iter().rev().sum();
            assert_close(s as f64, s2 as f64, 1e-5)
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |g| {
            let n = g.usize_in(1..100);
            require(n < 5, format!("n={n} too big"))
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(1, 3, 1.0);
        let mut b = Gen::new(1, 3, 1.0);
        for _ in 0..10 {
            assert_eq!(a.rng().next_u64(), b.rng().next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        check(200, |g| {
            let u = g.usize_in(3..17);
            let f = g.f32_in(-2.0..2.0);
            let i = g.i64_in(-5..5);
            require(
                (3..17).contains(&u) && (-2.0..2.0).contains(&f) && (-5..5).contains(&i),
                format!("out of range: {u} {f} {i}"),
            )
        });
    }

    #[test]
    fn assert_all_close_reports_index() {
        let e = assert_all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-6).unwrap_err();
        assert!(e.contains("index 1"));
        assert!(assert_all_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }
}
