//! Minimal JSON parser/serializer (serde is not in the vendored registry).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! kept as f64 (adequate for manifests, metrics and checkpoints metadata —
//! binary tensor data never goes through JSON).

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable key order), which keeps manifests diffable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- access
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as `i64`, if this is an integral number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    /// The value as `usize`, if this is a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field access; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` that errors with a readable path for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing required field '{key}'"))
    }

    // ------------------------------------------------------------ construct
    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Number value.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// String value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Array of numbers from an `f32` slice.
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Array of numbers from a `usize` slice.
    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---------------------------------------------------------------- parse
    /// Parse one JSON document from `text`.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {} of JSON input", p.i);
        }
        Ok(v)
    }

    /// Parse the JSON document in `path`.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // ------------------------------------------------------------ serialize
    /// Serialize deterministically (object keys sorted, stable float
    /// formatting) — byte-identical across runs for identical values.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Streaming line-at-a-time reader over an append-only JSONL stream
/// (`telemetry.jsonl` / `trace.jsonl`; schemas in
/// `docs/observability.md`).
///
/// Memory is O(longest line), independent of stream length: one reused
/// line buffer, one parsed [`Json`] value alive at a time — a
/// million-interval history diffs without ever materializing a
/// whole-file tree. Blank lines are skipped (a crashed writer may leave
/// a trailing one); a torn/invalid line surfaces as an `Err` item with
/// its line number so callers can choose to stop or skip.
pub struct JsonlReader<R: std::io::BufRead> {
    src: R,
    buf: String,
    line_no: usize,
}

impl JsonlReader<std::io::BufReader<std::fs::File>> {
    /// Open a JSONL file for streaming.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow!("opening {}: {e}", path.display()))?;
        Ok(Self::new(std::io::BufReader::new(file)))
    }
}

impl<R: std::io::BufRead> JsonlReader<R> {
    /// Reader over a JSONL source.
    pub fn new(src: R) -> Self {
        JsonlReader {
            src,
            buf: String::new(),
            line_no: 0,
        }
    }

    /// 1-based number of the line the last item came from.
    pub fn line_no(&self) -> usize {
        self.line_no
    }

    /// Next parsed line; `None` at end of stream.
    #[allow(clippy::should_implement_trait)]
    pub fn next(&mut self) -> Option<Result<Json>> {
        loop {
            self.buf.clear();
            match self.src.read_line(&mut self.buf) {
                Ok(0) => return None,
                Ok(_) => {}
                Err(e) => return Some(Err(anyhow!("reading line {}: {e}", self.line_no + 1))),
            }
            self.line_no += 1;
            let line = self.buf.trim();
            if line.is_empty() {
                continue;
            }
            return Some(
                Json::parse(line).map_err(|e| anyhow!("line {}: {e}", self.line_no)),
            );
        }
    }
}

impl<R: std::io::BufRead> Iterator for JsonlReader<R> {
    type Item = Result<Json>;

    fn next(&mut self) -> Option<Self::Item> {
        JsonlReader::next(self)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            )
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: join if a low surrogate follows
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.i += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad unicode escape"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("raw control char in string"),
                c => {
                    // re-scan multibyte UTF-8 from the source slice
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        if start == self.i {
            bail!("expected value at byte {}", start);
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64().unwrap(), 2);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_and_surrogates() {
        let v = Json::parse(r#""é 😀 é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é 😀 é");
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "tru", "{\"a\"}", "1 2", "\"\x01\""] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn scientific_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }

    #[test]
    fn req_reports_field() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("dims").unwrap_err().to_string();
        assert!(err.contains("dims"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ }").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn jsonl_reader_streams_lines_and_skips_blanks() {
        let text = "{\"a\":1}\n\n{\"a\":2}\n{\"a\":3}";
        let mut r = JsonlReader::new(std::io::Cursor::new(text));
        let mut seen = Vec::new();
        while let Some(item) = r.next() {
            seen.push(item.unwrap().get("a").unwrap().as_i64().unwrap());
        }
        assert_eq!(seen, vec![1, 2, 3]);
        assert_eq!(r.line_no(), 4);
    }

    #[test]
    fn jsonl_reader_reports_torn_line_with_number() {
        let text = "{\"ok\":true}\n{\"torn\":";
        let mut r = JsonlReader::new(std::io::Cursor::new(text));
        assert!(r.next().unwrap().is_ok());
        let err = r.next().unwrap().unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(r.next().is_none());
    }

    #[test]
    fn jsonl_reader_is_an_iterator() {
        let text = "1\n2\n3\n";
        let vals: Vec<i64> = JsonlReader::new(std::io::Cursor::new(text))
            .map(|j| j.unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(vals, vec![1, 2, 3]);
    }
}
