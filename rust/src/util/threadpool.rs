//! Fixed-size worker pool with bounded queues (tokio substitute).
//!
//! Four primitives:
//!
//! * [`bounded`] — a bounded MPSC channel with blocking `send`, the
//!   backpressure primitive the coordinator's prefetch pipeline uses.
//! * [`bands`] — the machine's clamped parallelism, the band count the
//!   band-parallel compute kernels in `tensor::ops` / `engine` /
//!   `nn::layers` target.
//! * [`scope`] — scoped-borrow dispatch over the persistent global pool:
//!   run a set of borrowed jobs (each owning a disjoint `chunks_mut`
//!   band of the output) on the pooled workers and block until all
//!   complete. This replaced the per-call `std::thread::scope` spawns in
//!   the band kernels (correct and copy-free, but paying OS thread
//!   creation on every large op); the only per-call cost now is one
//!   small box per band.
//! * [`ThreadPool`] — submit `'static` closures, optionally collect
//!   results via [`ThreadPool::scope_map`]; also hosts [`ThreadPool::scope`].

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// A borrowed band job handed to [`scope`]. Each job typically owns one
/// disjoint `chunks_mut` slice of the output buffer.
pub type ScopedJob<'a> = Box<dyn FnOnce() + Send + 'a>;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Pool with `threads` workers (floored to 1).
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pegrad-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // Busy/idle split: time inside `job()` is busy,
                            // time blocked in `recv()` is idle — the trace
                            // subsystem derives pool utilization from the
                            // busy total alone (idle = wall − busy). Off
                            // path: one dead branch, no clock read.
                            Ok(job) => {
                                if crate::trace::enabled() {
                                    let t0 = std::time::Instant::now();
                                    job();
                                    crate::trace::pool_busy(t0.elapsed().as_nanos() as u64);
                                } else {
                                    job();
                                }
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Number of workers (for chunking heuristics).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Fire-and-forget job submission.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Scoped-borrow dispatch: run the borrowed `jobs` on the pooled
    /// workers and block until every one of them has finished. The LAST
    /// job runs inline on the calling thread (one fewer queue hop, and
    /// the caller keeps making progress even when the pool is saturated
    /// by other callers); the rest go through the worker queue.
    ///
    /// Safety: the non-`'static` borrows inside the jobs are sound
    /// because this function does not return until the completion latch
    /// counts every dispatched job — the borrows strictly outlive the
    /// workers' use of them. A panicking job is caught on the worker (so
    /// the latch still completes and the pool worker survives) and its
    /// original payload is re-raised on the caller once all jobs settle.
    pub fn scope<'a>(&self, mut jobs: Vec<ScopedJob<'a>>) {
        let Some(last) = jobs.pop() else { return };
        let latch = Arc::new(Latch::new(jobs.len()));
        for job in jobs {
            // SAFETY: see above — `scope` blocks on the latch until the
            // job has run, so extending the closure's lifetime to
            // 'static never lets a borrow dangle.
            let job: ScopedJob<'static> = unsafe { std::mem::transmute(job) };
            let latch = Arc::clone(&latch);
            self.execute(move || {
                let payload =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).err();
                latch.complete(payload);
            });
        }
        // The inline job may panic; the latch MUST be drained first so no
        // borrowed job is still running when this frame unwinds.
        let inline = std::panic::catch_unwind(std::panic::AssertUnwindSafe(last)).err();
        let pooled = latch.wait();
        if let Some(payload) = inline.or(pooled) {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run `f(i)` for `i in 0..n` across the pool and collect results in
    /// order. Blocks until all complete. `f` must be cloneable across
    /// threads (typically a capture-by-Arc closure).
    pub fn scope_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let v = f(i);
                let _ = tx.send((i, v));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("worker died")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the queue; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Panic payload captured from a worker, carried back to the caller.
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Completion latch for [`ThreadPool::scope`]: counts outstanding jobs
/// down and keeps the first panic payload for re-raising on the caller.
struct Latch {
    state: Mutex<(usize, Option<PanicPayload>)>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new((n, None)),
            cv: Condvar::new(),
        }
    }

    fn complete(&self, payload: Option<PanicPayload>) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if st.1.is_none() {
            st.1 = payload;
        }
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job completed; returns the first panic payload,
    /// if any job panicked.
    fn wait(&self) -> Option<PanicPayload> {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1.take()
    }
}

/// [`ThreadPool::scope`] on the shared global pool — the band kernels'
/// dispatch point. Workers never block on latches (only callers do), so
/// concurrent callers contend but cannot deadlock.
pub fn scope(jobs: Vec<ScopedJob<'_>>) {
    global().scope(jobs);
}

/// Shared global pool sized to the machine, spawned on first use. The
/// band kernels dispatch their borrowed jobs here via [`scope`];
/// `'static` fire-and-forget work goes through [`ThreadPool::execute`].
pub fn global() -> &'static ThreadPool {
    use once_cell::sync::Lazy;
    static POOL: Lazy<ThreadPool> = Lazy::new(|| ThreadPool::new(bands()));
    &POOL
}

/// Row-band count compute kernels should target: the machine's available
/// parallelism with the pool's clamp, cached WITHOUT spawning the pool
/// (shape-only callers need the number, not the worker queue).
pub fn bands() -> usize {
    use std::sync::OnceLock;
    static BANDS: OnceLock<usize> = OnceLock::new();
    *BANDS.get_or_init(|| {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, 32)
    })
}

// ---------------------------------------------------------------------------
// Bounded channel (backpressure)
// ---------------------------------------------------------------------------

struct BoundedInner<T> {
    q: Mutex<BoundedState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct BoundedState<T> {
    buf: std::collections::VecDeque<T>,
    cap: usize,
    senders: usize,
    receiver_alive: bool,
}

/// Sending half of a bounded channel; `send` blocks when full.
pub struct BoundedSender<T>(Arc<BoundedInner<T>>);
/// Receiving half; `recv` blocks when empty, returns `None` when all
/// senders are gone and the buffer is drained.
pub struct BoundedReceiver<T>(Arc<BoundedInner<T>>);

/// Create a bounded channel of capacity `cap` (>=1).
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    assert!(cap >= 1);
    let inner = Arc::new(BoundedInner {
        q: Mutex::new(BoundedState {
            buf: std::collections::VecDeque::with_capacity(cap),
            cap,
            senders: 1,
            receiver_alive: true,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (BoundedSender(Arc::clone(&inner)), BoundedReceiver(inner))
}

impl<T> BoundedSender<T> {
    /// Blocking send; `Err(v)` if the receiver is gone.
    pub fn send(&self, v: T) -> Result<(), T> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if !st.receiver_alive {
                return Err(v);
            }
            if st.buf.len() < st.cap {
                st.buf.push_back(v);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        BoundedSender(Arc::clone(&self.0))
    }
}

impl<T> Drop for BoundedSender<T> {
    fn drop(&mut self) {
        let mut st = self.0.q.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> BoundedReceiver<T> {
    /// Blocking receive; `None` once all senders dropped and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.0.not_full.notify_one();
                return Some(v);
            }
            if st.senders == 0 {
                return None;
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.q.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            self.0.not_full.notify_one();
        }
        v
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        self.0.q.lock().unwrap().receiver_alive = false;
        self.0.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_map_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.scope_map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_zero() {
        let pool = ThreadPool::new(1);
        assert!(pool.scope_map(0, |i| i).is_empty());
    }

    #[test]
    fn scope_runs_borrowed_chunks() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0usize; 103]; // ragged last band
        let jobs: Vec<super::ScopedJob> = data
            .chunks_mut(10)
            .enumerate()
            .map(|(bi, chunk)| {
                Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = bi * 10 + i + 1;
                    }
                }) as super::ScopedJob
            })
            .collect();
        pool.scope(jobs);
        assert_eq!(data, (1..=103).collect::<Vec<_>>());
    }

    #[test]
    fn scope_empty_and_single() {
        let pool = ThreadPool::new(2);
        pool.scope(Vec::new());
        let mut hit = false;
        pool.scope(vec![Box::new(|| hit = true) as super::ScopedJob]);
        assert!(hit, "single job must run inline");
    }

    #[test]
    fn scope_keeps_workers_alive_after_many_rounds() {
        // the dispatch must be reusable thousands of times without
        // spawning threads (this is the whole point of the satellite)
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..2000 {
            let jobs: Vec<super::ScopedJob> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        total.fetch_add(1, Ordering::Relaxed);
                    }) as super::ScopedJob
                })
                .collect();
            pool.scope(jobs);
        }
        assert_eq!(total.load(Ordering::Relaxed), 8000);
        assert_eq!(pool.size(), 3);
    }

    #[test]
    fn scope_propagates_worker_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let jobs: Vec<super::ScopedJob> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("band boom");
                        }
                    }) as super::ScopedJob
                })
                .collect();
            pool.scope(jobs);
        }));
        assert!(caught.is_err(), "panic must propagate to the caller");
        // pool still functional afterwards
        let out = pool.scope_map(8, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_backpressure() {
        let (tx, rx) = bounded::<usize>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        // third send must block until a recv happens
        let t = thread::spawn(move || {
            tx.send(3).unwrap();
            "sent"
        });
        thread::sleep(Duration::from_millis(30));
        assert!(!t.is_finished(), "send should block at capacity");
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(t.join().unwrap(), "sent");
        assert_eq!(rx.recv(), Some(2));
        assert_eq!(rx.recv(), Some(3));
    }

    #[test]
    fn bounded_close_semantics() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(7));
        assert_eq!(rx.recv(), None); // senders gone
    }

    #[test]
    fn bounded_receiver_gone() {
        let (tx, rx) = bounded::<u32>(1);
        drop(rx);
        assert_eq!(tx.send(9), Err(9));
    }

    #[test]
    fn bounded_multi_sender() {
        let (tx, rx) = bounded::<usize>(8);
        let mut handles = vec![];
        for t in 0..4 {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..25 {
                    tx.send(t * 100 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got = vec![];
        while let Some(v) = rx.recv() {
            got.push(v);
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(got.len(), 100);
    }
}
