//! Support substrates the vendored registry does not provide.
//!
//! The offline build environment ships only the `xla` crate and its
//! transitive dependencies, so everything a framework normally pulls from
//! crates.io — JSON, logging, bench statistics, property testing, thread
//! pools — is implemented here (see DESIGN.md §6 Substitutions).
//!
//! (System map: `docs/architecture.md`.)

pub mod json;
pub mod logging;
pub mod prop;
pub mod stats;
pub mod threadpool;
pub mod timer;

pub use json::{Json, JsonlReader};
pub use stats::Summary;
pub use timer::Timer;
