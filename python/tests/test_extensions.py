"""§6 generalization tests: the Zbar-modification pattern beyond clipping."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M, pegrad

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def _batch(spec, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(spec.m, spec.dims[0])).astype(np.float32))
    if spec.loss == "softmax_ce":
        y = jnp.asarray(rng.integers(0, spec.dims[-1], spec.m).astype(np.int32))
    else:
        y = jnp.asarray(rng.normal(size=(spec.m, spec.dims[-1]))
                        .astype(np.float32))
    return x, y


class TestGradsNormalized:
    @given(t=st.floats(0.1, 10.0), seed=st.integers(0, 10**6))
    def test_each_example_hits_target_norm(self, t, seed):
        spec = M.ModelSpec(dims=(6, 9, 4), m=5)
        params = M.init_params(spec, seed % 1000)
        x, y = _batch(spec, seed)
        out = pegrad.grads_normalized(spec, params, x, y, t,
                                      use_pallas=False)
        # grads are the MEAN of normalized per-example grads; verify via the
        # identity: normalized-mean equals mean of (t/||g_j||) g_j.  Check by
        # reconstructing per-example grads with vmap.
        from compile import naive
        pex = naive._per_example_grads(spec, params, x, y)
        n = spec.n_layers
        want = []
        s = sum(jnp.sum(jnp.square(g), axis=(1, 2)) for g in pex)
        coef = t / jnp.sqrt(jnp.maximum(s, 1e-24))
        for g in pex:
            want.append(jnp.mean(g * coef[:, None, None], axis=0))
        got = out[1:1 + n]
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-6)

    def test_normalized_examples_have_equal_influence(self):
        """After normalization every example's gradient has norm t, so the
        per-example contribution norms are identical."""
        spec = M.ModelSpec(dims=(4, 7, 3), m=6)
        params = M.init_params(spec, 3)
        x, y = _batch(spec, 4)
        # scale one example's input hugely: raw norms differ wildly
        x = x.at[2].mul(25.0)
        out = pegrad.grads_normalized(spec, params, x, y, 1.0,
                                      use_pallas=False)
        s_total = out[-1]
        assert float(jnp.max(s_total) / jnp.min(s_total)) > 10.0, \
            "precondition: raw norms should be spread out"

    def test_pallas_matches_ref_path(self):
        spec = M.get_spec("tiny")
        params = M.init_params(spec, 0)
        x, y = _batch(spec, 1)
        a = pegrad.grads_normalized(spec, params, x, y, 2.0, use_pallas=True)
        b = pegrad.grads_normalized(spec, params, x, y, 2.0, use_pallas=False)
        for ta, tb in zip(a, b):
            np.testing.assert_allclose(ta, tb, rtol=1e-4, atol=1e-6)


def test_spec_n_layers_property():
    assert M.get_spec("tiny").n_layers == 3
