"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block overrides; fixed cases pin the edge
geometry (single row, single column, non-divisible tiles, zero and huge
inputs).  These are the core correctness signal for the trick's O(mnp)
kernels — everything downstream assumes them.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.row_norms import pick_block

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _arr(rng, m, k, dtype=np.float32, scale=1.0):
    return jnp.asarray((rng.normal(size=(m, k)) * scale).astype(dtype))


shapes = st.tuples(st.integers(1, 67), st.integers(1, 311))
dtypes = st.sampled_from([np.float32, jnp.bfloat16])


class TestRowSqNorms:
    @given(shape=shapes, seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, seed):
        rng = np.random.default_rng(seed)
        x = _arr(rng, *shape)
        got = kernels.row_sq_norms(x)
        want = ref.row_sq_norms(x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    @given(shape=shapes, bm=st.integers(1, 16), bk=st.integers(1, 64))
    def test_any_block_shape(self, shape, bm, bk):
        rng = np.random.default_rng(0)
        x = _arr(rng, *shape)
        got = kernels.row_sq_norms(x, block=(bm, bk))
        np.testing.assert_allclose(got, ref.row_sq_norms(x),
                                   rtol=1e-5, atol=1e-6)

    def test_bf16_accumulates_f32(self):
        # 1024 values of 1.0 in bf16: an f32 accumulator sums exactly.
        x = jnp.ones((2, 1024), jnp.bfloat16)
        got = kernels.row_sq_norms(x)
        assert got.dtype == jnp.float32
        np.testing.assert_array_equal(np.asarray(got), [1024.0, 1024.0])

    @pytest.mark.parametrize("m,k", [(1, 1), (1, 500), (500, 1), (8, 128)])
    def test_edge_geometry(self, m, k):
        rng = np.random.default_rng(42)
        x = _arr(rng, m, k)
        np.testing.assert_allclose(kernels.row_sq_norms(x),
                                   ref.row_sq_norms(x), rtol=1e-5, atol=1e-6)

    def test_zeros(self):
        x = jnp.zeros((5, 37))
        np.testing.assert_array_equal(np.asarray(kernels.row_sq_norms(x)),
                                      np.zeros(5))

    def test_large_magnitude(self):
        x = jnp.full((3, 7), 1e10, jnp.float32)
        np.testing.assert_allclose(kernels.row_sq_norms(x),
                                   ref.row_sq_norms(x), rtol=1e-6)


class TestPegradNorms:
    @given(m=st.integers(1, 40), pz=st.integers(1, 130),
           ph=st.integers(1, 130), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, pz, ph, seed):
        rng = np.random.default_rng(seed)
        z, h = _arr(rng, m, pz), _arr(rng, m, ph)
        np.testing.assert_allclose(kernels.pegrad_norms(z, h),
                                   ref.pegrad_norms(z, h),
                                   rtol=1e-5, atol=1e-6)

    @given(bm=st.integers(1, 17))
    def test_row_block_override(self, bm):
        rng = np.random.default_rng(7)
        z, h = _arr(rng, 33, 50), _arr(rng, 33, 20)
        np.testing.assert_allclose(kernels.pegrad_norms(z, h, bm=bm),
                                   ref.pegrad_norms(z, h),
                                   rtol=1e-5, atol=1e-6)

    def test_wide_rows_fall_back_to_tiled(self):
        # Force the VMEM-overflow path: bm floor * (pz+ph) * 4 > budget.
        rng = np.random.default_rng(3)
        z, h = _arr(rng, 8, 70_000), _arr(rng, 8, 70_000)
        got = kernels.pegrad_norms(z, h)
        np.testing.assert_allclose(got, ref.pegrad_norms(z, h), rtol=1e-4)

    def test_batch_mismatch_raises(self):
        with pytest.raises(AssertionError):
            kernels.pegrad_norms(jnp.zeros((3, 4)), jnp.zeros((4, 4)))


class TestClipScale:
    @given(m=st.integers(1, 40), p=st.integers(1, 130),
           c=st.floats(0.01, 100.0), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, p, c, seed):
        rng = np.random.default_rng(seed)
        z = _arr(rng, m, p)
        s = ref.row_sq_norms(z) * np.abs(rng.normal(size=m)).astype(np.float32)
        s = jnp.asarray(s)
        np.testing.assert_allclose(
            kernels.clip_scale(z, s, jnp.float32(c)),
            ref.clip_scale(z, s, c), rtol=1e-5, atol=1e-6)

    def test_clip_actually_bounds_norm(self):
        rng = np.random.default_rng(0)
        z = _arr(rng, 16, 64, scale=10.0)
        s = ref.row_sq_norms(z)  # single-layer: s IS the total sq norm
        c = 1.0
        zc = kernels.clip_scale(z, s, jnp.float32(c))
        norms = np.sqrt(np.asarray(ref.row_sq_norms(zc)))
        assert (norms <= c * (1 + 1e-5)).all()

    def test_rows_below_bound_untouched(self):
        rng = np.random.default_rng(0)
        z = _arr(rng, 8, 16, scale=0.01)
        s = ref.row_sq_norms(z)
        zc = kernels.clip_scale(z, s, jnp.float32(100.0))
        np.testing.assert_allclose(zc, z, rtol=1e-6)

    def test_zero_row_stays_zero_not_nan(self):
        z = jnp.zeros((4, 8))
        s = jnp.zeros((4,))
        zc = np.asarray(kernels.clip_scale(z, s, jnp.float32(1.0)))
        assert np.isfinite(zc).all() and (zc == 0).all()


class TestMatmulT:
    @given(m=st.integers(1, 50), k=st.integers(1, 70), p=st.integers(1, 70),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, m, k, p, seed):
        rng = np.random.default_rng(seed)
        h, z = _arr(rng, m, k), _arr(rng, m, p)
        np.testing.assert_allclose(kernels.matmul_t(h, z),
                                   ref.matmul_t(h, z),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bm,bk,bp", [(8, 8, 8), (128, 128, 128),
                                          (16, 32, 64)])
    def test_tile_shapes(self, bm, bk, bp):
        rng = np.random.default_rng(1)
        h, z = _arr(rng, 70, 50, scale=0.5), _arr(rng, 70, 90, scale=0.5)
        got = kernels.matmul_t(h, z, bm=bm, bk=bk, bp=bp)
        np.testing.assert_allclose(got, ref.matmul_t(h, z),
                                   rtol=1e-4, atol=1e-4)

    def test_is_transpose_matmul(self):
        rng = np.random.default_rng(2)
        h, z = _arr(rng, 10, 5), _arr(rng, 10, 7)
        np.testing.assert_allclose(kernels.matmul_t(h, z),
                                   np.asarray(h).T @ np.asarray(z),
                                   rtol=1e-5, atol=1e-5)


class TestStaticModels:
    """The §Perf estimators are pure functions — pin their invariants."""

    def test_pick_block_fits_budget(self):
        for m, k in [(1, 1), (64, 1024), (4096, 65536), (7, 100000)]:
            bm, bk = pick_block(m, k)
            assert bm * bk * 4 <= kernels.row_norms.VMEM_BUDGET \
                if hasattr(kernels, "row_norms") else bm * bk * 4 <= 4 << 20
            assert 1 <= bm and 1 <= bk

    def test_vmem_estimate_consistent(self):
        est = kernels.vmem_estimate(64, 1024)
        assert est["hbm_read_bytes"] == 64 * 1024 * 4
        assert est["flops"] == 2 * 64 * 1024
        bm, bk = est["block"]
        assert est["vmem_bytes"] == bm * bk * 4 + bm * 4

    def test_mxu_estimate_aligned_is_full_util(self):
        est = kernels.mxu_estimate(128, 256, 384)
        assert est["mxu_utilization"] == pytest.approx(1.0)

    def test_mxu_estimate_ragged_below_one(self):
        est = kernels.mxu_estimate(100, 200, 300)
        assert 0 < est["mxu_utilization"] < 1.0
