"""L2 model structure tests: specs, init, forward capture, losses."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def _batch(spec, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(spec.m, spec.dims[0])).astype(np.float32))
    if spec.loss == "softmax_ce":
        y = jnp.asarray(rng.integers(0, spec.dims[-1], spec.m).astype(np.int32))
    else:
        y = jnp.asarray(rng.normal(size=(spec.m, spec.dims[-1]))
                        .astype(np.float32))
    return x, y


class TestSpec:
    def test_weight_shapes_fold_bias(self):
        spec = M.ModelSpec(dims=(4, 8, 3))
        assert spec.weight_shapes() == [(5, 8), (9, 3)]
        assert spec.param_count() == 5 * 8 + 9 * 3

    def test_flops_model(self):
        spec = M.ModelSpec(dims=(4, 8, 3), m=2)
        fwd = 2 * 2 * (5 * 8 + 9 * 3)
        assert spec.flops_forward() == fwd
        assert spec.flops_backward() == fwd + 2 * 2 * 9 * 3

    @pytest.mark.parametrize("bad", [
        dict(dims=(4,)),
        dict(dims=(4, 8), activation="nope"),
        dict(dims=(4, 8), loss="nope"),
        dict(dims=(4, 8), m=0),
    ])
    def test_validation(self, bad):
        with pytest.raises(ValueError):
            M.ModelSpec(**bad)

    def test_all_presets_construct(self):
        for name in M.PRESETS:
            spec = M.get_spec(name)
            assert spec.param_count() > 0
        assert M.get_spec("mlp100m").param_count() > 95_000_000

    def test_unknown_preset(self):
        with pytest.raises(KeyError):
            M.get_spec("nonexistent")


class TestInit:
    def test_deterministic(self):
        spec = M.get_spec("tiny")
        a = M.init_params(spec, seed=5)
        b = M.init_params(spec, seed=5)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_seed_changes_weights(self):
        spec = M.get_spec("tiny")
        a, b = M.init_params(spec, 0), M.init_params(spec, 1)
        assert not np.allclose(a[0], b[0])

    def test_bias_row_zero(self):
        spec = M.get_spec("small")
        for w in M.init_params(spec):
            np.testing.assert_array_equal(np.asarray(w)[-1, :], 0.0)

    def test_he_scale(self):
        spec = M.ModelSpec(dims=(1000, 1000, 10), activation="relu")
        w = np.asarray(M.init_params(spec)[0])[:-1]
        assert np.std(w) == pytest.approx(np.sqrt(2 / 1000), rel=0.1)


class TestForward:
    def test_capture_shapes(self):
        spec = M.ModelSpec(dims=(4, 8, 6, 3), m=5)
        params = M.init_params(spec)
        x, _ = _batch(spec)
        logits, hs, zs = M.forward(spec, params, x)
        assert logits.shape == (5, 3)
        assert [h.shape for h in hs] == [(5, 5), (5, 9), (5, 7)]
        assert [z.shape for z in zs] == [(5, 8), (5, 6), (5, 3)]

    def test_augment_adds_ones(self):
        h = jnp.zeros((3, 2))
        ha = M.augment(h)
        np.testing.assert_array_equal(np.asarray(ha)[:, -1], 1.0)

    def test_final_layer_linear(self):
        # last z must equal logits (no activation on the output layer)
        spec = M.ModelSpec(dims=(4, 8, 3), m=2, activation="relu")
        params = M.init_params(spec, 1)
        x, _ = _batch(spec)
        logits, _, zs = M.forward(spec, params, x)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(zs[-1]))

    @given(act=st.sampled_from(sorted(M.ACTIVATIONS)))
    def test_activations_run(self, act):
        spec = M.ModelSpec(dims=(3, 4, 2), m=2, activation=act)
        logits, _, _ = M.forward(spec, M.init_params(spec), _batch(spec)[0])
        assert np.isfinite(np.asarray(logits)).all()

    def test_eps_shifts_z(self):
        spec = M.ModelSpec(dims=(3, 4, 2), m=2)
        params = M.init_params(spec, 2)
        x, _ = _batch(spec)
        eps = [jnp.ones((2, 4)), jnp.zeros((2, 2))]
        _, _, zs0 = M.forward(spec, params, x)
        _, _, zs1 = M.forward(spec, params, x, eps=eps)
        np.testing.assert_allclose(np.asarray(zs1[0]),
                                   np.asarray(zs0[0]) + 1.0, rtol=1e-6)


class TestLosses:
    def test_ce_matches_manual(self):
        spec = M.ModelSpec(dims=(2, 3), m=4, loss="softmax_ce")
        logits = jnp.asarray(np.random.default_rng(0)
                             .normal(size=(4, 3)).astype(np.float32))
        y = jnp.asarray([0, 1, 2, 1], dtype=jnp.int32)
        got = M.per_example_loss(spec, logits, y)
        p = np.exp(np.asarray(logits))
        p /= p.sum(1, keepdims=True)
        want = -np.log(p[np.arange(4), np.asarray(y)])
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_mse_matches_manual(self):
        spec = M.ModelSpec(dims=(2, 3), m=4, loss="mse")
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
        got = M.per_example_loss(spec, a, b)
        want = ((np.asarray(a) - np.asarray(b)) ** 2).mean(1)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_loss_single_consistent_with_batch(self):
        spec = M.ModelSpec(dims=(4, 8, 3), m=6)
        params = M.init_params(spec, 3)
        x, y = _batch(spec, 9)
        logits, _, _ = M.forward(spec, params, x)
        batched = np.asarray(M.per_example_loss(spec, logits, y))
        for j in range(spec.m):
            single = float(M.loss_single(spec, params, x[j], y[j]))
            assert single == pytest.approx(batched[j], rel=1e-5)

    def test_ce_nonnegative_and_sane_at_init(self):
        spec = M.get_spec("tiny")
        params = M.init_params(spec)
        x, y = _batch(spec)
        logits, _, _ = M.forward(spec, params, x)
        loss = np.asarray(M.per_example_loss(spec, logits, y))
        assert (loss >= 0).all()
        # ~ln(10) at random init
        assert 0.5 < loss.mean() < 6.0
