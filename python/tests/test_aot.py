"""AOT pipeline tests: lowering, manifest integrity, HLO text sanity.

These guard the interchange contract the rust loader depends on; a manifest
or calling-convention drift here breaks L3 at runtime, so the tests pin it
at build time.
"""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.main(["--out-dir", out, "--presets", "tiny"])
    with open(os.path.join(out, "manifest.json")) as f:
        return out, json.load(f)


EXPECTED_ENTRIES = {
    "fwd", "norms_pegrad", "grads_pegrad", "grads_normalized",
    "step_vanilla", "step_pegrad", "step_clipped", "grad_batch1",
    "norms_naive", "step_clipped_naive",
}


class TestManifest:
    def test_format_and_entries(self, built):
        _, man = built
        assert man["format_version"] == aot.FORMAT_VERSION
        tiny = man["presets"]["tiny"]
        assert set(tiny["entries"]) == EXPECTED_ENTRIES
        assert tiny["dims"] == [16, 32, 32, 10]
        assert tiny["m"] == 8
        assert tiny["param_count"] == M.get_spec("tiny").param_count()

    def test_files_exist_and_parse(self, built):
        out, man = built
        for e in man["presets"]["tiny"]["entries"].values():
            path = os.path.join(out, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert text.startswith("HloModule")
            assert "ENTRY" in text

    def test_input_shapes_match_spec(self, built):
        _, man = built
        spec = M.get_spec("tiny")
        ins = man["presets"]["tiny"]["entries"]["norms_pegrad"]["inputs"]
        wshapes = spec.weight_shapes()
        for i, (a, b) in enumerate(wshapes):
            assert ins[i]["shape"] == [a, b]
        assert ins[len(wshapes)]["shape"] == [spec.m, spec.dims[0]]
        assert ins[len(wshapes) + 1]["dtype"] == "int32"

    def test_output_arity(self, built):
        _, man = built
        ent = man["presets"]["tiny"]["entries"]
        n = M.get_spec("tiny").n_layers
        assert len(ent["fwd"]["outputs"]) == 3
        assert len(ent["norms_pegrad"]["outputs"]) == 3
        assert len(ent["step_vanilla"]["outputs"]) == n + 1
        assert len(ent["step_pegrad"]["outputs"]) == n + 3
        assert len(ent["step_clipped"]["outputs"]) == n + 3
        assert len(ent["grads_pegrad"]["outputs"]) == n + 3

    def test_norms_pegrad_output_shapes(self, built):
        _, man = built
        spec = M.get_spec("tiny")
        outs = man["presets"]["tiny"]["entries"]["norms_pegrad"]["outputs"]
        assert outs[0]["shape"] == [spec.m]
        assert outs[1]["shape"] == [spec.m, spec.n_layers]
        assert outs[2]["shape"] == [spec.m]

    def test_rebuild_merges_presets(self, built, tmp_path):
        """Re-running aot for another preset must not drop existing ones."""
        out, _ = built
        aot.main(["--out-dir", out, "--presets", "sweep64"])
        with open(os.path.join(out, "manifest.json")) as f:
            man = json.load(f)
        assert {"tiny", "sweep64"} <= set(man["presets"])


class TestHloText:
    def test_pallas_and_ref_variants_agree_numerically(self, tmp_path):
        """interpret-mode Pallas and the jnp oracle lower to HLO that
        computes the same function (executed via jax here; rust re-checks
        through PJRT in its integration tests)."""
        from compile import pegrad
        spec = M.get_spec("tiny")
        params = M.init_params(spec, 0)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(spec.m, spec.dims[0]))
                        .astype(np.float32))
        y = jnp.asarray(rng.integers(0, 10, spec.m).astype(np.int32))
        a = pegrad.norms_pegrad(spec, params, x, y, use_pallas=True)
        b = pegrad.norms_pegrad(spec, params, x, y, use_pallas=False)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-5)

    def test_op_histogram(self):
        text = ("HloModule m\n"
                "ENTRY e {\n"
                "  a = f32[2,2]{1,0} parameter(0)\n"
                "  b = f32[2,2]{1,0} dot(a, a)\n"
                "  c = f32[2,2]{1,0} add(b, b)\n"
                "  d = f32[2,2]{1,0} add(c, c)\n"
                "}\n")
        hist = aot.hlo_op_histogram(text)
        assert hist["add"] == 2
        assert hist["dot"] == 1

    def test_scalar_knobs_are_rank1(self, built):
        _, man = built
        ins = man["presets"]["tiny"]["entries"]["step_clipped"]["inputs"]
        # trailing knobs: lr, clip_c, sigma (f32[1]) and seed (i32[1])
        assert [i["shape"] for i in ins[-4:]] == [[1], [1], [1], [1]]
        assert ins[-1]["dtype"] == "int32"
