"""The paper's central claims, tested as theorems.

* §4: trick norms == naive (vmap) norms, exactly, for arbitrary
  architectures, activations and losses (hypothesis generates the specs).
* §6: trick-clipped step == naive-clipped step; clipped norms respect C.
* step_pegrad with uniform weights == step_vanilla.
* grads_pegrad == jax.grad of the mean loss.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M, naive, pegrad

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def _batch(spec, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(spec.m, spec.dims[0])).astype(np.float32))
    if spec.loss == "softmax_ce":
        y = jnp.asarray(rng.integers(0, spec.dims[-1], spec.m).astype(np.int32))
    else:
        y = jnp.asarray(rng.normal(size=(spec.m, spec.dims[-1]))
                        .astype(np.float32))
    return x, y


random_specs = st.builds(
    M.ModelSpec,
    dims=st.lists(st.integers(2, 24), min_size=2, max_size=5).map(tuple),
    activation=st.sampled_from(["relu", "tanh", "gelu", "sigmoid"]),
    loss=st.sampled_from(["softmax_ce", "mse"]),
    m=st.integers(1, 12),
)


class TestTheorem:
    """Paper §4: s_j^(i) = ||Zbar_j||² ||Haug_j||² equals the explicit norm."""

    @given(spec=random_specs, seed=st.integers(0, 2**31 - 1))
    def test_trick_equals_naive(self, spec, seed):
        params = M.init_params(spec, seed % 1000)
        x, y = _batch(spec, seed)
        s_t, sl_t, _ = pegrad.norms_pegrad(spec, params, x, y,
                                           use_pallas=False)
        s_n, sl_n = naive.norms_naive(spec, params, x, y)
        np.testing.assert_allclose(s_t, s_n, rtol=5e-4, atol=1e-7)
        np.testing.assert_allclose(sl_t, sl_n, rtol=5e-4, atol=1e-7)

    @pytest.mark.parametrize("preset", ["tiny", "small"])
    def test_trick_equals_naive_presets_with_pallas(self, preset):
        spec = M.get_spec(preset)
        params = M.init_params(spec, 1)
        x, y = _batch(spec, 2)
        s_t, sl_t, _ = pegrad.norms_pegrad(spec, params, x, y,
                                           use_pallas=True)
        s_n, sl_n = naive.norms_naive(spec, params, x, y)
        np.testing.assert_allclose(s_t, s_n, rtol=5e-4)
        np.testing.assert_allclose(sl_t, sl_n, rtol=5e-4)

    def test_trick_equals_batch1_loop(self):
        """The literal §3 naive method (m separate backprops) agrees too."""
        spec = M.ModelSpec(dims=(5, 7, 4), m=6)
        params = M.init_params(spec, 4)
        x, y = _batch(spec, 5)
        s_t, _, _ = pegrad.norms_pegrad(spec, params, x, y, use_pallas=False)
        for j in range(spec.m):
            out = naive.grad_batch1(spec, params, x[j], y[j])
            grads = out[1:]
            s_j = sum(float(jnp.sum(jnp.square(g))) for g in grads)
            assert s_j == pytest.approx(float(s_t[j]), rel=1e-4)

    def test_norm_includes_bias_gradient(self):
        """Haug's constant-1 column makes s cover the bias term exactly."""
        spec = M.ModelSpec(dims=(3, 2), m=4, loss="mse")
        params = M.init_params(spec, 0)
        x, y = _batch(spec, 1)
        s_t, _, _ = pegrad.norms_pegrad(spec, params, x, y, use_pallas=False)
        # manual: per-example grad of W (incl. bias row) for a linear model
        for j in range(3):
            g = jax.grad(lambda p: M.loss_single(spec, p, x[j], y[j]))(params)
            manual = float(sum(jnp.sum(jnp.square(gi)) for gi in g))
            assert manual == pytest.approx(float(s_t[j]), rel=1e-4)

    def test_per_layer_norms_are_components(self):
        spec = M.get_spec("tiny")
        params = M.init_params(spec)
        x, y = _batch(spec)
        s, sl, _ = pegrad.norms_pegrad(spec, params, x, y, use_pallas=False)
        np.testing.assert_allclose(np.asarray(sl).sum(1), s, rtol=1e-6)
        assert (np.asarray(sl) >= 0).all()


class TestGrads:
    def test_grads_pegrad_equal_jax_grad(self):
        spec = M.get_spec("tiny")
        params = M.init_params(spec, 7)
        x, y = _batch(spec, 8)
        out = pegrad.grads_pegrad(spec, params, x, y, use_pallas=False)
        grads = out[1:1 + spec.n_layers]

        def mean_loss(p):
            logits, _, _ = M.forward(spec, p, x)
            return jnp.mean(M.per_example_loss(spec, logits, y))

        for a, b in zip(grads, jax.grad(mean_loss)(params)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)

    def test_step_pegrad_uniform_equals_vanilla(self):
        spec = M.get_spec("tiny")
        params = M.init_params(spec, 2)
        x, y = _batch(spec, 3)
        lr = 0.05
        w = jnp.full((spec.m,), 1.0 / spec.m)
        out_p = pegrad.step_pegrad(spec, params, x, y, lr, w,
                                   use_pallas=False)
        out_v = pegrad.step_vanilla(spec, params, x, y, lr)
        for a, b in zip(out_p[:spec.n_layers], out_v[:spec.n_layers]):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
        assert float(out_p[spec.n_layers]) == pytest.approx(
            float(out_v[spec.n_layers]), rel=1e-5)

    def test_is_weights_reweight_linearly(self):
        """Doubling one example's weight adds exactly its gradient once."""
        spec = M.ModelSpec(dims=(4, 3), m=4, loss="mse")
        params = M.init_params(spec, 5)
        x, y = _batch(spec, 6)
        base = jnp.full((4,), 0.25)
        bumped = base.at[2].add(0.25)
        o1 = pegrad.step_pegrad(spec, params, x, y, 1.0, base,
                                use_pallas=False)
        o2 = pegrad.step_pegrad(spec, params, x, y, 1.0, bumped,
                                use_pallas=False)
        g2 = jax.grad(lambda p: M.loss_single(spec, p, x[2], y[2]))(params)
        for w_new1, w_new2, g in zip(o1[:1], o2[:1], g2[:1]):
            np.testing.assert_allclose(
                np.asarray(w_new1) - np.asarray(w_new2),
                0.25 * np.asarray(g), rtol=1e-4, atol=1e-6)


class TestClipped:
    """Paper §6 extension."""

    @given(spec=random_specs, c=st.floats(0.05, 10.0),
           seed=st.integers(0, 10**6))
    def test_trick_clip_equals_naive_clip(self, spec, c, seed):
        params = M.init_params(spec, seed % 997)
        x, y = _batch(spec, seed)
        a = pegrad.step_clipped(spec, params, x, y, 0.1, c, 0.0, 0,
                                use_pallas=False)
        b = naive.step_clipped_naive(spec, params, x, y, 0.1, c, 0.0, 0)
        for wa, wb in zip(a[:spec.n_layers], b[:spec.n_layers]):
            np.testing.assert_allclose(wa, wb, rtol=2e-3, atol=1e-5)
        # s_total and clip_frac agree
        np.testing.assert_allclose(a[spec.n_layers + 1],
                                   b[spec.n_layers + 1], rtol=5e-4,
                                   atol=1e-7)

    def test_clipped_update_bounded(self):
        """||param update|| <= lr * C when sigma=0 (the DP-SGD guarantee)."""
        spec = M.ModelSpec(dims=(6, 8, 4), m=8)
        params = M.init_params(spec, 1)
        x, y = _batch(spec, 2)
        x = x * 50.0  # force huge gradients
        lr, c = 1.0, 0.5
        out = pegrad.step_clipped(spec, params, x, y, lr, c, 0.0, 0,
                                  use_pallas=False)
        upd_sq = sum(float(jnp.sum(jnp.square(w - nw)))
                     for w, nw in zip(params, out[:spec.n_layers]))
        # mean of m clipped grads, each norm <= C  ->  ||upd|| <= lr*C
        assert np.sqrt(upd_sq) <= lr * c * (1 + 1e-4)

    def test_noise_changes_update_deterministically(self):
        spec = M.ModelSpec(dims=(3, 2), m=2, loss="mse")
        params = M.init_params(spec, 0)
        x, y = _batch(spec, 0)
        a = pegrad.step_clipped(spec, params, x, y, 0.1, 1.0, 1.0, 42,
                                use_pallas=False)
        b = pegrad.step_clipped(spec, params, x, y, 0.1, 1.0, 1.0, 42,
                                use_pallas=False)
        c = pegrad.step_clipped(spec, params, x, y, 0.1, 1.0, 1.0, 43,
                                use_pallas=False)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert not np.allclose(np.asarray(a[0]), np.asarray(c[0]))

    def test_clip_frac(self):
        spec = M.ModelSpec(dims=(3, 2), m=4, loss="mse")
        params = M.init_params(spec, 0)
        x, y = _batch(spec, 0)
        out_hi = pegrad.step_clipped(spec, params, x, y, 0.1, 1e9, 0.0, 0,
                                     use_pallas=False)
        out_lo = pegrad.step_clipped(spec, params, x, y, 0.1, 1e-9, 0.0, 0,
                                     use_pallas=False)
        assert float(out_hi[-1]) == 0.0
        assert float(out_lo[-1]) == 1.0


class TestIntermediates:
    def test_zbar_matches_manual_chain_rule_linear(self):
        """For a 1-layer linear+MSE model, Zbar has a closed form."""
        spec = M.ModelSpec(dims=(3, 2), m=5, loss="mse")
        params = M.init_params(spec, 9)
        x, y = _batch(spec, 10)
        _, _, hs, zbars = pegrad.backprop_intermediates(spec, params, x, y)
        logits, _, _ = M.forward(spec, params, x)
        want = 2.0 / spec.dims[-1] * (np.asarray(logits) - np.asarray(y))
        np.testing.assert_allclose(np.asarray(zbars[0]), want, rtol=1e-5)
        # hs[0] is the augmented input
        np.testing.assert_allclose(np.asarray(hs[0])[:, :-1], np.asarray(x),
                                   rtol=1e-6)

    def test_softmax_zbar_rows_sum_to_zero(self):
        spec = M.ModelSpec(dims=(3, 4), m=5, loss="softmax_ce")
        params = M.init_params(spec, 0)
        x, y = _batch(spec, 0)
        _, _, _, zbars = pegrad.backprop_intermediates(spec, params, x, y)
        np.testing.assert_allclose(np.asarray(zbars[-1]).sum(1),
                                   np.zeros(5), atol=1e-6)
