"""L2: the paper's contribution — per-example gradient norms and the §6
clipped-update extension, plus every training-step entry point the rust
coordinator executes.

Key function: :func:`backprop_intermediates` extracts ``Zbar^(i) = dC/dZ^(i)``
(and the forward's ``Haug^(i-1)``) with ONE forward + ONE backward pass via
the epsilon trick: write ``z = haug @ W + eps`` with ``eps = 0`` and take
``grad`` w.r.t. eps.  XLA fuses this into exactly the standard backward
pass — there is no extra compute versus ``jax.grad(loss)(params)`` (E1/E2
verify this empirically; `aot.py --report` shows the HLO op histograms).

From the intermediates:

* parameter gradients:  ``Wbar^(i) = Haug^(i-1)^T @ Zbar^(i)``     (standard)
* per-example norms:    ``s_j^(i) = ||Zbar_j||^2 * ||Haug_j||^2``  (paper §4)
* clipped gradients:    rescale rows of Zbar, redo only the matmul (paper §6)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import model as M
from . import kernels
from .kernels import ref as kref


def _k(use_pallas: bool):
    """Select the L1 implementation: Pallas kernels or the jnp oracles."""
    return kernels if use_pallas else kref


# ---------------------------------------------------------------------------
# Core: one fwd + one bwd -> (loss stats, Haug list, Zbar list)
# ---------------------------------------------------------------------------

def backprop_intermediates(spec: M.ModelSpec, params, x, y):
    """Run the standard batched backward pass, returning its intermediates.

    Returns:
      per_ex_loss: [m] unreduced losses L^(j)
      logits:      [m, d_n]
      hs:          list of Haug^(i-1), shape [m, d_{i-1}+1]
      zbars:       list of Zbar^(i) = dC/dZ^(i), shape [m, d_i]
                   (C = SUM of per-example losses, so row j is exactly
                   dL^(j)/dz_j — no minibatch averaging baked in)
    """
    m = x.shape[0]
    eps = [jnp.zeros((m, d), jnp.float32) for d in spec.dims[1:]]

    def f(eps_list):
        total, aux = M.loss_and_aux(spec, params, x, y, eps=eps_list)
        return total, aux

    grads, (per_ex, logits, hs, _zs) = jax.grad(f, has_aux=True)(eps)
    return per_ex, logits, hs, grads


def norms_from_intermediates(hs, zbars, use_pallas: bool):
    """Paper §4 applied per layer: s_layers[m, n], s_total[m]."""
    k = _k(use_pallas)
    per_layer = [k.pegrad_norms(zb, h) for zb, h in zip(zbars, hs)]
    s_layers = jnp.stack(per_layer, axis=1)
    return s_layers, jnp.sum(s_layers, axis=1)


def grads_from_intermediates(hs, zbars, weights=None, use_pallas=False):
    """``Wbar^(i) = Haug^T @ (diag(w) Zbar)`` — the final backprop step.

    ``weights`` (shape [m]) folds minibatch averaging / importance-sampling
    reweighting into the same matmul; None means plain SUM (paper's C).
    """
    k = _k(use_pallas)
    out = []
    for h, zb in zip(hs, zbars):
        if weights is not None:
            zb = zb * weights[:, None].astype(zb.dtype)
        out.append(k.matmul_t(h, zb))
    return out


# ---------------------------------------------------------------------------
# Entry points lowered by aot.py (each becomes one HLO artifact)
# ---------------------------------------------------------------------------

def fwd(spec: M.ModelSpec, params, x, y):
    """(mean_loss, per_ex_loss, logits) — evaluation."""
    logits, _, _ = M.forward(spec, params, x)
    per_ex = M.per_example_loss(spec, logits, y)
    return jnp.mean(per_ex), per_ex, logits


def norms_pegrad(spec: M.ModelSpec, params, x, y, *, use_pallas=True):
    """(s_total[m], s_layers[m,n], per_ex_loss[m]) — the headline entry.

    One batched fwd+bwd plus O(mnp) kernel work (paper §4/§5).
    """
    per_ex, _logits, hs, zbars = backprop_intermediates(spec, params, x, y)
    s_layers, s_total = norms_from_intermediates(hs, zbars, use_pallas)
    return s_total, s_layers, per_ex


def grads_pegrad(spec: M.ModelSpec, params, x, y, *, use_pallas=True):
    """(mean_loss, grads..., s_total, s_layers) — for rust-side optimizers."""
    per_ex, _logits, hs, zbars = backprop_intermediates(spec, params, x, y)
    s_layers, s_total = norms_from_intermediates(hs, zbars, use_pallas)
    m = x.shape[0]
    w = jnp.full((m,), 1.0 / m, jnp.float32)
    grads = grads_from_intermediates(hs, zbars, w, use_pallas)
    return (jnp.mean(per_ex), *grads, s_total, s_layers)


def step_vanilla(spec: M.ModelSpec, params, x, y, lr):
    """Plain SGD step, no per-example machinery (E2/E3 baseline)."""
    def mean_loss(p):
        logits, _, _ = M.forward(spec, p, x)
        return jnp.mean(M.per_example_loss(spec, logits, y))

    loss, grads = jax.value_and_grad(mean_loss)(params)
    new = [w - lr * g.astype(w.dtype) for w, g in zip(params, grads)]
    return (*new, loss)


def step_pegrad(spec: M.ModelSpec, params, x, y, lr, is_weights,
                *, use_pallas=True):
    """SGD step with importance-sampling weights + per-example norms.

    ``is_weights[j]`` is the unbiased reweighting coefficient the rust
    sampler computed (1/(N p_j) normalized to mean 1/m); passing uniform
    1/m reproduces ``step_vanilla`` exactly.
    """
    per_ex, _logits, hs, zbars = backprop_intermediates(spec, params, x, y)
    s_layers, s_total = norms_from_intermediates(hs, zbars, use_pallas)
    grads = grads_from_intermediates(hs, zbars, is_weights, use_pallas)
    new = [w - lr * g.astype(w.dtype) for w, g in zip(params, grads)]
    return (*new, jnp.mean(per_ex), s_total, s_layers)


def grads_normalized(spec: M.ModelSpec, params, x, y, target_norm,
                     *, use_pallas=True):
    """Paper §6, second instance of the general Zbar-modification pattern:
    rescale every example's gradient to a COMMON norm (``target_norm``),
    the normalized-gradient / sign-SGD-flavoured variant some importance
    samplers pair with norm-proportional selection.

    Same mechanics as clipping: coef_j = t/||g_j|| applied to Zbar rows,
    then one extra matmul per layer.  Returns (mean_loss, grads..., s_total).
    """
    k = _k(use_pallas)
    m = x.shape[0]
    per_ex, _logits, hs, zbars = backprop_intermediates(spec, params, x, y)
    _s_layers, s_total = norms_from_intermediates(hs, zbars, use_pallas)
    coef = target_norm / jnp.sqrt(jnp.maximum(s_total, 1e-24))
    zprime = [zb * coef[:, None].astype(zb.dtype) for zb in zbars]
    grads = [k.matmul_t(h, zb) / m for h, zb in zip(hs, zprime)]
    return (jnp.mean(per_ex), *grads, s_total)


def step_clipped(spec: M.ModelSpec, params, x, y, lr, clip_c, noise_sigma,
                 seed, *, use_pallas=True):
    """Paper §6 + Gaussian mechanism = DP-SGD, via the trick.

    1. one batched fwd+bwd -> Haug, Zbar            (standard cost)
    2. s_j via the §4 factorization                  (O(mnp))
    3. Zbar' = clip_scale(Zbar, s, C)                (O(mnp))
    4. Wbar' = Haug^T @ Zbar'                        (ONE extra matmul/layer)
    5. add sigma*C gaussian noise, average, SGD step

    Returns (*params', mean_loss, s_total, clip_frac).
    """
    k = _k(use_pallas)
    m = x.shape[0]
    per_ex, _logits, hs, zbars = backprop_intermediates(spec, params, x, y)
    s_layers, s_total = norms_from_intermediates(hs, zbars, use_pallas)
    zprime = [k.clip_scale(zb, s_total, clip_c) for zb in zbars]
    grads = grads_from_intermediates(hs, zprime, None, use_pallas)
    key = jax.random.PRNGKey(seed)
    new = []
    for i, (w, g) in enumerate(zip(params, grads)):
        key, sub = jax.random.split(key)
        noise = jax.random.normal(sub, g.shape, jnp.float32)
        g = (g + noise_sigma * clip_c * noise) / m
        new.append(w - lr * g.astype(w.dtype))
    clip_frac = jnp.mean((jnp.sqrt(s_total) > clip_c).astype(jnp.float32))
    return (*new, jnp.mean(per_ex), s_total, clip_frac)
