"""AOT driver: lower every L2 entry point to an HLO-text artifact.

This is the single place Python runs in the whole system — ``make
artifacts`` invokes it once per preset; the rust coordinator only ever
touches the emitted files.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  Lowering goes jitted-fn -> StableHLO -> XlaComputation
(``return_tuple=True``) -> ``as_hlo_text()``.

Calling convention baked into every artifact (and recorded in
``manifest.json`` for the rust loader):

* inputs: ``W1..Wn`` (each ``[d_{i-1}+1, d_i]``, bias folded as last row),
  then the entry's data arguments, then scalar knobs as ``f32[1]`` /
  ``i32[1]`` arrays (the ``xla`` crate builds rank-1 literals trivially).
* outputs: always a tuple (even 1-tuples) — unwrap per manifest arity.
"""

from __future__ import annotations

import argparse
import collections
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import naive
from . import pegrad

FORMAT_VERSION = 2

# Presets whose vmap-naive artifacts would need O(m * params) memory at
# runtime; we skip those entries there (documented in DESIGN.md §4/E2).
_SKIP_NAIVE_ABOVE_PARAMS = 30_000_000

DEFAULT_PRESETS = [
    "tiny", "small", "base", "wide",
    "sweep64", "sweep128", "sweep256", "sweep512", "sweep1024",
    "mlp100m",
]


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _scalarize(fn, n_scalars: int, int_scalars=()):
    """Adapt trailing scalar args to shape-[1] array args (rust-friendly)."""
    @functools.wraps(fn)
    def wrapped(*args):
        head = args[:-n_scalars] if n_scalars else args
        tail = [a[0] for a in args[len(head):]]
        return fn(*head, *tail)
    return wrapped


def entry_points(spec: M.ModelSpec, use_pallas: bool = True):
    """entry name -> (callable taking flat args, list of example args).

    ``params`` are spread as the leading arguments so the artifact signature
    is a flat list of arrays.
    """
    n = spec.n_layers
    wshapes = [_f32(*s) for s in spec.weight_shapes()]
    X = _f32(spec.m, spec.dims[0])
    if spec.loss == "softmax_ce":
        Y = _i32(spec.m)
    else:
        Y = _f32(spec.m, spec.dims[-1])
    x1 = _f32(spec.dims[0])
    y1 = _i32() if spec.loss == "softmax_ce" else _f32(spec.dims[-1])
    S = _f32(1)   # f32 scalar knob
    I = _i32(1)   # i32 scalar knob

    def take_params(fn, n_extra_scalars=0):
        def flat(*args):
            params = list(args[:n])
            return fn(params, *args[n:])
        return _scalarize(flat, n_extra_scalars)

    ep = {
        "fwd": (take_params(functools.partial(pegrad.fwd, spec)),
                [*wshapes, X, Y]),
        "norms_pegrad": (take_params(functools.partial(
            pegrad.norms_pegrad, spec, use_pallas=use_pallas)),
            [*wshapes, X, Y]),
        "grads_pegrad": (take_params(functools.partial(
            pegrad.grads_pegrad, spec, use_pallas=use_pallas)),
            [*wshapes, X, Y]),
        "step_vanilla": (take_params(functools.partial(
            pegrad.step_vanilla, spec), 1),
            [*wshapes, X, Y, S]),
        "step_clipped": (take_params(functools.partial(
            pegrad.step_clipped, spec, use_pallas=use_pallas), 4),
            [*wshapes, X, Y, S, S, S, I]),
        "grad_batch1": (take_params(functools.partial(
            naive.grad_batch1, spec)),
            [*wshapes, x1, y1]),
        "grads_normalized": (take_params(functools.partial(
            pegrad.grads_normalized, spec, use_pallas=use_pallas), 1),
            [*wshapes, X, Y, S]),
    }
    # step_pegrad signature: params, X, Y, lr(f32[1]), is_weights[m] — its
    # scalar knob is not trailing, so it gets a bespoke flattener below.
    ep["step_pegrad"] = (_step_pegrad_flat(spec, use_pallas),
                         [*wshapes, X, Y, S, _f32(spec.m)])

    if spec.param_count() <= _SKIP_NAIVE_ABOVE_PARAMS:
        ep["norms_naive"] = (take_params(functools.partial(
            naive.norms_naive, spec)), [*wshapes, X, Y])
        ep["step_clipped_naive"] = (take_params(functools.partial(
            naive.step_clipped_naive, spec), 4),
            [*wshapes, X, Y, S, S, S, I])
    return ep


# step_pegrad's lr/is_weights are (S, [m]); adapt scalars manually since the
# scalar knob (lr) is not trailing.  Simplest: wrap here.
def _step_pegrad_flat(spec, use_pallas):
    n = spec.n_layers

    def flat(*args):
        params = list(args[:n])
        x, y, lr, w = args[n], args[n + 1], args[n + 2], args[n + 3]
        return pegrad.step_pegrad(spec, params, x, y, lr[0], w,
                                  use_pallas=use_pallas)
    return flat


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def hlo_op_histogram(text: str) -> dict[str, int]:
    """Crude HLO op histogram for the --report perf evidence."""
    hist = collections.Counter()
    for line in text.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith(("HloModule", "ENTRY", "}")):
            continue
        rhs = line.split("=", 1)[1].strip()
        # "f32[64,256]{1,0} dot(...)" -> "dot"
        parts = rhs.split(" ")
        if len(parts) >= 2:
            op = parts[1].split("(")[0]
            hist[op] += 1
    return dict(hist)


def _shape_info(avals):
    out = []
    for a in avals:
        out.append({"dtype": str(a.dtype), "shape": [int(d) for d in a.shape]})
    return out


def build_preset(name: str, out_dir: str, use_pallas: bool = True,
                 report: bool = False) -> dict:
    spec = M.get_spec(name)
    eps = entry_points(spec, use_pallas)
    pdir = os.path.join(out_dir, name)
    os.makedirs(pdir, exist_ok=True)
    entries = {}
    for ename, (fn, example_args) in sorted(eps.items()):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        rel = f"{name}/{ename}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        if not isinstance(out_avals, tuple):
            out_avals = (out_avals,)
        entries[ename] = {
            "file": rel,
            "inputs": _shape_info(example_args),
            "outputs": _shape_info(out_avals),
        }
        if report:
            hist = hlo_op_histogram(text)
            top = sorted(hist.items(), key=lambda kv: -kv[1])[:8]
            print(f"  {name}/{ename}: {len(text)//1024}KiB hlo, "
                  f"ops={sum(hist.values())} top={top}")
        else:
            print(f"  wrote {rel} ({len(text)//1024} KiB)")
    return {
        "dims": list(spec.dims),
        "activation": spec.activation,
        "loss": spec.loss,
        "m": spec.m,
        "dtype": spec.dtype,
        "n_layers": spec.n_layers,
        "param_count": spec.param_count(),
        "flops_forward": spec.flops_forward(),
        "flops_backward": spec.flops_backward(),
        "use_pallas": use_pallas,
        "entries": entries,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", nargs="*", default=DEFAULT_PRESETS)
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the pure-jnp oracle kernels instead of Pallas")
    ap.add_argument("--report", action="store_true",
                    help="print HLO op histograms (L2 perf evidence)")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"format_version": FORMAT_VERSION, "presets": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("format_version") == FORMAT_VERSION:
            manifest = old

    for preset in args.presets:
        print(f"preset {preset}:")
        manifest["presets"][preset] = build_preset(
            preset, args.out_dir, use_pallas=not args.no_pallas,
            report=args.report)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest['presets'])} presets)")


if __name__ == "__main__":
    main()
