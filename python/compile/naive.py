"""L2: the paper's §3 NAIVE baselines.

Two naive strategies are materialized as artifacts:

* ``norms_naive`` / ``step_clipped_naive`` — vmap over ``jax.grad`` of the
  single-example loss.  This is the *best possible* implementation of the
  naive idea on a modern stack (it keeps minibatch parallelism but
  materializes every per-example weight gradient: O(m * params) memory and
  roughly doubles the backward flops, paper §5).
* ``grad_batch1`` — the literal naive method: one backprop at minibatch
  size 1; the rust E2 driver calls it m times per batch.  This is the
  variant the paper says "performs very poorly because back-propagation is
  most efficient when ... minibatch operations" — we measure exactly that.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M


def _per_example_grads(spec: M.ModelSpec, params, x, y):
    """[m, ...]-stacked gradients of each example's own loss."""
    def gfn(x1, y1):
        return jax.grad(
            lambda p: M.loss_single(spec, p, x1, y1))(params)
    return jax.vmap(gfn)(x, y)


def norms_naive(spec: M.ModelSpec, params, x, y):
    """(s_total[m], s_layers[m,n]) via explicit per-example gradients."""
    pex_grads = _per_example_grads(spec, params, x, y)
    per_layer = [jnp.sum(jnp.square(g.astype(jnp.float32)), axis=(1, 2))
                 for g in pex_grads]
    s_layers = jnp.stack(per_layer, axis=1)
    return jnp.sum(s_layers, axis=1), s_layers


def grad_batch1(spec: M.ModelSpec, params, x1, y1):
    """(loss, grads...) for ONE example — the m-calls-per-batch baseline."""
    def f(p):
        return M.loss_single(spec, p, x1, y1)

    loss, grads = jax.value_and_grad(f)(params)
    return (loss, *grads)


def step_clipped_naive(spec: M.ModelSpec, params, x, y, lr, clip_c,
                       noise_sigma, seed):
    """DP-SGD step clipping each materialized per-example gradient.

    Semantically identical to ``pegrad.step_clipped`` (pytest asserts this);
    the cost difference is E3.
    """
    m = x.shape[0]
    pex_grads = _per_example_grads(spec, params, x, y)
    s_total = sum(jnp.sum(jnp.square(g.astype(jnp.float32)), axis=(1, 2))
                  for g in pex_grads)
    norm = jnp.sqrt(jnp.maximum(s_total, 1e-30))
    coef = jnp.minimum(1.0, clip_c / norm)
    key = jax.random.PRNGKey(seed)
    new = []
    for w, g in zip(params, pex_grads):
        clipped = jnp.sum(g * coef[:, None, None].astype(g.dtype), axis=0)
        key, sub = jax.random.split(key)
        noise = jax.random.normal(sub, clipped.shape, jnp.float32)
        gm = (clipped + noise_sigma * clip_c * noise) / m
        new.append(w - lr * gm.astype(w.dtype))
    logits, _, _ = M.forward(spec, params, x)
    mean_loss = jnp.mean(M.per_example_loss(spec, logits, y))
    clip_frac = jnp.mean((norm > clip_c).astype(jnp.float32))
    return (*new, mean_loss, s_total, clip_frac)
