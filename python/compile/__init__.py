"""pegrad build-time package: L1 Pallas kernels + L2 JAX model + AOT driver.

Never imported at runtime — the rust coordinator only consumes the HLO-text
artifacts this package emits via ``make artifacts``.
"""
