"""L2: the paper's model — an n-layer dense network with explicit H/Z capture.

Paper §2 problem definition, implemented exactly:

    z^(i) = h^(i-1)^T W^(i)       (minibatched: Z^(i) = Haug^(i-1) @ W^(i))
    h^(i) = phi^(i)(z^(i))

Biases are folded in as the *last row* of each ``W^(i)`` and the layer input
is augmented with a constant-1 column ("the phi function from the layer
below providing a constant input of 1 to this column").  Consequently the
per-example gradient norms produced by the trick automatically include the
bias gradients — ``||haug||^2 = ||h||^2 + 1``.

The loss is a function of the final ``z`` and the targets only; it never
touches the parameters directly, which is the paper's stated requirement
for the trick to hold.

Everything here is build-time Python: :mod:`compile.aot` lowers jitted
wrappers of these functions to HLO text once, and the rust L3 executes the
artifacts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
    "sigmoid": jax.nn.sigmoid,
    "identity": lambda z: z,
}

LOSSES = ("softmax_ce", "mse")


@dataclass(frozen=True)
class ModelSpec:
    """Static description of one model variant (mirrors manifest.json)."""

    dims: tuple[int, ...]          # (d0, d1, ..., dn): input, hidden..., output
    activation: str = "relu"      # hidden activation phi
    loss: str = "softmax_ce"
    m: int = 32                    # minibatch size baked into the artifacts
    dtype: str = "f32"

    def __post_init__(self):
        if len(self.dims) < 2:
            raise ValueError(f"need >=2 dims, got {self.dims}")
        if self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}")
        if self.loss not in LOSSES:
            raise ValueError(f"unknown loss {self.loss!r}")
        if self.m < 1:
            raise ValueError(f"batch size must be >=1, got {self.m}")

    @property
    def n_layers(self) -> int:
        return len(self.dims) - 1

    @property
    def jdtype(self):
        return {"f32": jnp.float32, "bf16": jnp.bfloat16}[self.dtype]

    def weight_shapes(self) -> list[tuple[int, int]]:
        """Shape of each W^(i): (d_{i-1} + 1, d_i) — bias folded as last row."""
        return [(self.dims[i] + 1, self.dims[i + 1])
                for i in range(self.n_layers)]

    def param_count(self) -> int:
        return sum(a * b for a, b in self.weight_shapes())

    def flops_forward(self) -> int:
        """Matmul flops of one forward pass at batch m (2*m*k*p per layer)."""
        return sum(2 * self.m * a * b for a, b in self.weight_shapes())

    def flops_backward(self) -> int:
        """dW = H^T Zbar plus dH = Zbar W^T per layer (no dH for layer 1)."""
        shapes = self.weight_shapes()
        f = sum(2 * self.m * a * b for a, b in shapes)           # dW
        f += sum(2 * self.m * a * b for a, b in shapes[1:])      # dH
        return f

    def input_example(self):
        return jnp.zeros((self.m, self.dims[0]), self.jdtype)

    def target_example(self):
        if self.loss == "softmax_ce":
            return jnp.zeros((self.m,), jnp.int32)
        return jnp.zeros((self.m, self.dims[-1]), self.jdtype)


def init_params(spec: ModelSpec, seed: int = 0) -> list[jax.Array]:
    """He (relu/gelu) or Glorot (tanh/sigmoid/identity) init; zero bias row."""
    key = jax.random.PRNGKey(seed)
    params = []
    he = spec.activation in ("relu", "gelu")
    for i, (fan_in_p1, fan_out) in enumerate(spec.weight_shapes()):
        key, sub = jax.random.split(key)
        fan_in = fan_in_p1 - 1
        if he:
            std = math.sqrt(2.0 / fan_in)
        else:
            std = math.sqrt(2.0 / (fan_in + fan_out))
        w = jax.random.normal(sub, (fan_in, fan_out), jnp.float32) * std
        w = jnp.concatenate([w, jnp.zeros((1, fan_out), jnp.float32)], axis=0)
        params.append(w.astype(spec.jdtype))
    return params


def augment(h: jax.Array) -> jax.Array:
    """Append the constant-1 bias column (paper §2)."""
    m = h.shape[0]
    return jnp.concatenate([h, jnp.ones((m, 1), h.dtype)], axis=1)


def forward(spec: ModelSpec, params, x, *, eps=None):
    """Forward pass capturing the trick's ingredients.

    Args:
      eps: optional list of zero tensors with the shapes of each ``Z^(i)``.
        When provided, ``z = haug @ W + eps_i`` — differentiating the summed
        loss w.r.t. ``eps_i`` yields exactly ``Zbar^(i) = dC/dZ^(i)``, which
        is how :mod:`compile.pegrad` extracts the backprop intermediates
        without re-deriving the chain rule by hand.

    Returns:
      (logits, hs, zs) where ``hs[i]`` is the *augmented* ``H^(i)`` input to
      layer i+1 (``hs[0]`` is the augmented network input, paper's H^(0)).
    """
    act = ACTIVATIONS[spec.activation]
    h = x
    hs, zs = [], []
    n = spec.n_layers
    for i, w in enumerate(params):
        ha = augment(h)
        hs.append(ha)
        z = ha @ w
        if eps is not None:
            z = z + eps[i]
        zs.append(z)
        h = act(z) if i < n - 1 else z
    return h, hs, zs


def per_example_loss(spec: ModelSpec, logits, y):
    """L^(j) for each example j (unreduced)."""
    if spec.loss == "softmax_ce":
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
    # mse: mean over output dims so the scale is width-independent.
    d = (logits.astype(jnp.float32) - y.astype(jnp.float32))
    return jnp.mean(d * d, axis=-1)


def loss_and_aux(spec: ModelSpec, params, x, y, *, eps=None):
    """Summed loss C (paper's total cost) + everything the trick needs."""
    logits, hs, zs = forward(spec, params, x, eps=eps)
    per_ex = per_example_loss(spec, logits, y)
    return jnp.sum(per_ex), (per_ex, logits, hs, zs)


def loss_single(spec: ModelSpec, params, x1, y1):
    """Loss of ONE example (for the naive vmap/batch-1 baselines)."""
    logits, _, _ = forward(spec, params, x1[None, :])
    y = y1[None] if spec.loss == "softmax_ce" else y1[None, :]
    return per_example_loss(spec, logits, y)[0]


# ---------------------------------------------------------------------------
# Presets (mirrored in DESIGN.md §2 and rust config presets)
# ---------------------------------------------------------------------------

PRESETS: dict[str, ModelSpec] = {
    "tiny": ModelSpec(dims=(16, 32, 32, 10), m=8),
    "small": ModelSpec(dims=(64, 256, 256, 10), m=32),
    "base": ModelSpec(dims=(256, 1024, 1024, 1024, 10), m=64),
    "wide": ModelSpec(dims=(256, 4096, 4096, 10), m=64),
    "mlp100m": ModelSpec(dims=(1024, 6656, 6656, 6656, 1024), m=32),
}

# Equal-width sweep presets for E1/E2 (p in {64..1024}, n=3 hidden matmuls).
for _p in (64, 128, 256, 512, 1024):
    PRESETS[f"sweep{_p}"] = ModelSpec(dims=(_p, _p, _p, _p), m=64,
                                      loss="mse")

# Batch-size sweep presets for E2's "gap grows with m" axis (p=256, n=3).
for _m in (8, 16, 32, 128, 256):
    PRESETS[f"m{_m}"] = ModelSpec(dims=(256, 256, 256, 256), m=_m,
                                  loss="mse")


def get_spec(name: str) -> ModelSpec:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown preset {name!r}; available: {sorted(PRESETS)}") from None
