"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness references: pytest (plus hypothesis shape/dtype
sweeps) asserts the Pallas kernels match these to tight tolerances.  They
are also used directly by the L2 model when ``use_pallas=False`` — which
gives an A/B path for isolating kernel bugs from model bugs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def row_sq_norms(x: jax.Array) -> jax.Array:
    """``out[j] = sum_k x[j,k]^2`` with f32 accumulation."""
    xf = x.astype(jnp.float32)
    return jnp.sum(xf * xf, axis=1)


def pegrad_norms(zbar: jax.Array, h: jax.Array) -> jax.Array:
    """Paper §4: ``s[j] = ||zbar[j]||^2 * ||h[j]||^2``."""
    return row_sq_norms(zbar) * row_sq_norms(h)


def clip_scale(zbar: jax.Array, s_total: jax.Array,
               clip_c: jax.Array) -> jax.Array:
    """Paper §6: rescale rows so each example's TOTAL grad norm ≤ C."""
    norm = jnp.sqrt(jnp.maximum(s_total, 1e-30))
    coef = jnp.minimum(1.0, jnp.asarray(clip_c, jnp.float32) / norm)
    return zbar * coef[:, None].astype(zbar.dtype)


def matmul_t(h: jax.Array, zbar: jax.Array) -> jax.Array:
    """Paper §6: ``Wbar' = H^T @ Zbar'`` with f32 accumulation."""
    return jax.lax.dot_general(
        h, zbar,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
