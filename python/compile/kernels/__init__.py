"""L1 Pallas kernels for pegrad (build-time only; lowered into L2 HLO).

The L2 model takes ``use_pallas`` as a parameter so the AOT layer can emit
both variants (Pallas vs pure-jnp oracle) and the test suite can diff them.
"""

from . import ref
from .clip import clip_scale
from .matmul_t import matmul_t, mxu_estimate
from .row_norms import pegrad_norms, pick_block, row_sq_norms, vmem_estimate

__all__ = [
    "ref",
    "clip_scale",
    "matmul_t",
    "mxu_estimate",
    "pegrad_norms",
    "pick_block",
    "row_sq_norms",
    "vmem_estimate",
]
