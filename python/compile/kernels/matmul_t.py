"""L1 Pallas kernel: MXU-tiled transposed matmul ``dW = H^T @ Zbar``.

This is the §6 "re-run the final step of backpropagation" recompute:

    Wbar^(i)' = X^(i)T Zbar^(i)'        (paper's X == our H, bias-augmented)

On TPU this is MXU work.  Hardware adaptation (DESIGN.md §5): where a CUDA
implementation would tile over threadblocks with shared-memory staging, we
tile ``(bk, bp)`` output blocks with an f32 VMEM accumulator and walk the
contraction (m) axis as the *innermost* grid dimension so the accumulator
block stays resident across the whole contraction.  Tiles are 128-aligned
to match the 128x128 systolic array.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .row_norms import _ceil_div

MXU = 128


def _matmul_t_kernel(h_ref, z_ref, o_ref):
    i = pl.program_id(2)  # contraction (m) axis — innermost

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    h = h_ref[...]
    z = z_ref[...]
    # f32 accumulation regardless of operand dtype (MXU semantics).
    o_ref[...] += jax.lax.dot_general(
        h, z,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


def matmul_t(h: jax.Array, zbar: jax.Array, *,
             bm: int = MXU, bk: int = MXU, bp: int = MXU,
             interpret: bool = True) -> jax.Array:
    """``out[k, p] = sum_j h[j, k] * zbar[j, p]`` with MXU-aligned tiling.

    Args:
      h: ``[m, k]`` layer input (bias-augmented).
      zbar: ``[m, p]`` (possibly clip-rescaled) backprop intermediate.
    """
    m, k = h.shape
    m2, p = zbar.shape
    assert m == m2, f"contraction mismatch: {m} vs {m2}"
    bm, bk, bp = min(bm, m), min(bk, k), min(bp, p)
    # Zero-pad the contraction (m) dim to a tile multiple: interpret-mode
    # Pallas NaN-fills out-of-bounds input blocks, which would poison the
    # accumulator (zero rows contribute nothing to the contraction).
    if m % bm:
        pad = bm - m % bm
        h = jnp.pad(h, ((0, pad), (0, 0)))
        zbar = jnp.pad(zbar, ((0, pad), (0, 0)))
        m = h.shape[0]
    grid = (_ceil_div(k, bk), _ceil_div(p, bp), _ceil_div(m, bm))
    return pl.pallas_call(
        _matmul_t_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda a, b, i: (i, a)),
            pl.BlockSpec((bm, bp), lambda a, b, i: (i, b)),
        ],
        out_specs=pl.BlockSpec((bk, bp), lambda a, b, i: (a, b)),
        out_shape=jax.ShapeDtypeStruct((k, p), jnp.float32),
        interpret=interpret,
    )(h, zbar)


def mxu_estimate(m: int, k: int, p: int,
                 bm: int = MXU, bk: int = MXU, bp: int = MXU) -> dict:
    """Static MXU-utilization model for DESIGN/EXPERIMENTS §Perf."""
    import math
    bm_, bk_, bp_ = min(bm, m), min(bk, k), min(bp, p)
    tiles = _ceil_div(k, bk_) * _ceil_div(p, bp_) * _ceil_div(m, bm_)
    flops = 2 * m * k * p
    padded = 2 * tiles * bm_ * bk_ * bp_
    return {
        "grid": (_ceil_div(k, bk_), _ceil_div(p, bp_), _ceil_div(m, bm_)),
        "vmem_bytes": (bm_ * bk_ + bm_ * bp_) * 4 + bk_ * bp_ * 4,
        "flops": flops,
        "mxu_utilization": flops / padded if padded else 0.0,
        "hbm_read_bytes": 4 * (math.prod((m, k)) * _ceil_div(p, bp_)
                               + math.prod((m, p)) * _ceil_div(k, bk_)),
        "hbm_write_bytes": 4 * k * p,
    }
