"""L1 Pallas kernel: per-example row rescale (paper §6 extension).

After the trick produces the per-example total squared norm ``s_j``, the §6
extension modifies the backprop intermediates row-wise:

    zbar'[j, :] = coef[j] * zbar[j, :]

For gradient clipping to bound C, ``coef[j] = min(1, C / sqrt(s_j))``.  The
coefficient computation is a cheap O(m) vector op done in-kernel from ``s``
so the clipped stream never materializes an intermediate coefficient array
in HBM; the rescale itself is elementwise and tiled like ``row_sq_norms``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .row_norms import _ceil_div, pick_block


def _clip_kernel(z_ref, s_ref, c_ref, o_ref):
    s = s_ref[...]
    c = c_ref[0]
    # rsqrt with a floor keeps the coefficient finite for zero-gradient rows
    # (a zero row stays zero regardless, so the value chosen is irrelevant,
    # but NaN would poison the multiply).
    norm = jnp.sqrt(jnp.maximum(s, 1e-30))
    coef = jnp.minimum(1.0, c / norm)
    o_ref[...] = z_ref[...] * coef[:, None].astype(z_ref.dtype)


def clip_scale(zbar: jax.Array, s_total: jax.Array, clip_c: jax.Array,
               *, block: tuple[int, int] | None = None,
               interpret: bool = True) -> jax.Array:
    """Rescale each row of ``zbar`` to clip its example's gradient norm.

    Args:
      zbar: ``[m, p]`` backprop intermediate for one layer.
      s_total: ``[m]`` per-example TOTAL squared gradient norm (summed over
        all layers) — the clip decision is global per example, applied to
        every layer's zbar with the same coefficient.
      clip_c: scalar clip bound ``C`` (f32 array, shape ``[1]``).
    """
    m, p = zbar.shape
    bm, bk = block or pick_block(m, p)
    bm, bk = min(bm, m), min(bk, p)
    grid = (_ceil_div(m, bm), _ceil_div(p, bk))
    return pl.pallas_call(
        _clip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), zbar.dtype),
        interpret=interpret,
    )(zbar, s_total, jnp.asarray(clip_c, jnp.float32).reshape(1))
