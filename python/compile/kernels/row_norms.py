"""L1 Pallas kernels: row-wise squared-norm reductions.

These implement the O(mnp) "extra work" of the Goodfellow trick (paper §4):

    s_j^(i) = (sum_k Zbar_{j,k}^(i)^2) * (sum_k H_{j,k}^(i-1)^2)

Two kernels are provided:

* ``row_sq_norms(x)`` — tiled row-wise sum of squares.  The k dimension is
  blocked so arbitrarily wide layers stream through VMEM one ``(bm, bk)``
  tile at a time; the output block is revisited across the k grid axis and
  used as the accumulator (Pallas guarantees sequential grid iteration on
  TPU, so the revisited output ref is the idiomatic reduction pattern).
* ``pegrad_norms(zbar, h)`` — the fused product ``rowsq(zbar) * rowsq(h)``.
  Both operands are row-blocked only (full rows resident in VMEM) so the
  product never round-trips partial norms through HBM.  Use when
  ``bm * (pz + ph) * 4`` bytes fits the VMEM budget; otherwise compose two
  ``row_sq_norms`` calls (the AOT layer picks automatically).

All kernels run ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, so interpret mode lowers them to plain HLO.  On real
TPU the same BlockSpecs compile unchanged (drop ``interpret``).

Hardware adaptation note (DESIGN.md §5): this is bandwidth-bound VPU work —
the tiles are chosen to read each element of Zbar/H from HBM exactly once,
reusing what backprop already materialized, which is the paper's entire
point restated for the memory hierarchy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget used when auto-picking block shapes (bytes).  Real TPU cores
# have 16 MiB; we stay well under half so double-buffering fits.
VMEM_BUDGET = 4 * 1024 * 1024

# Lane width of the VPU; the last dimension of a block should be a multiple
# of this for full vector-register utilization.
LANE = 128
# Sublane height for f32.
SUBLANE = 8


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pick_block(m: int, k: int, budget: int = VMEM_BUDGET) -> tuple[int, int]:
    """Choose a (bm, bk) tile for an (m, k) f32 operand.

    Prefers full-width k tiles (one HBM pass, unit-stride lanes); shrinks k
    in LANE multiples only when a full row exceeds the budget.
    """
    bm = min(m, 256)
    bk = min(k, 2048)
    while bm * bk * 4 > budget and bk > LANE:
        bk = max(LANE, bk // 2)
    while bm * bk * 4 > budget and bm > SUBLANE:
        bm = max(SUBLANE, bm // 2)
    return bm, bk


def _row_sq_kernel(x_ref, o_ref):
    """Accumulate sum-of-squares of the current tile into the output rows.

    Grid axis 1 walks the k dimension; the output block depends only on the
    row-grid index, so Pallas revisits it and we accumulate in place.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.sum(tile * tile, axis=1)


def row_sq_norms(x: jax.Array, *, block: tuple[int, int] | None = None,
                 interpret: bool = True) -> jax.Array:
    """Row-wise sum of squares: ``out[j] = sum_k x[j, k]**2`` (f32).

    Accumulation is always f32 even for bf16 inputs (matches MXU/VPU
    accumulator behaviour and keeps the norm usable for clipping).
    """
    m, k = x.shape
    bm, bk = block or pick_block(m, k)
    bm, bk = min(bm, m), min(bk, k)
    # Zero-pad the reduction dim to a tile multiple: out-of-bounds input
    # blocks are NaN-filled in interpret mode and would poison the row sums
    # (zeros contribute nothing to a sum of squares, so this is exact).
    if k % bk:
        x = jnp.pad(x, ((0, 0), (0, bk - k % bk)))
        k = x.shape[1]
    grid = (_ceil_div(m, bm), _ceil_div(k, bk))
    return pl.pallas_call(
        _row_sq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(x)


def _pegrad_kernel(z_ref, h_ref, o_ref):
    """Fused s = rowsq(zbar) * rowsq(h) for one block of rows."""
    z = z_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(z * z, axis=1) * jnp.sum(h * h, axis=1)


def pegrad_norms(zbar: jax.Array, h: jax.Array, *, bm: int | None = None,
                 interpret: bool = True) -> jax.Array:
    """Per-example squared gradient norm for one dense layer (paper §4).

    ``s[j] = ||zbar[j]||^2 * ||h[j]||^2`` where ``h`` is the layer input
    *including* the folded bias column.  Falls back to two tiled
    ``row_sq_norms`` passes when full rows of both operands do not fit the
    VMEM budget.
    """
    m, pz = zbar.shape
    m2, ph = h.shape
    assert m == m2, f"batch mismatch: {m} vs {m2}"
    if bm is None:
        bm = min(m, 256)
        while bm * (pz + ph) * 4 > VMEM_BUDGET and bm > SUBLANE:
            bm = max(SUBLANE, bm // 2)
    if bm * (pz + ph) * 4 > VMEM_BUDGET:
        # Rows too wide even at minimum height: compose tiled reductions.
        return row_sq_norms(zbar, interpret=interpret) * row_sq_norms(
            h, interpret=interpret)
    grid = (_ceil_div(m, bm),)
    return pl.pallas_call(
        _pegrad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, pz), lambda i: (i, 0)),
            pl.BlockSpec((bm, ph), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=interpret,
    )(zbar, h)


def vmem_estimate(m: int, k: int, block: tuple[int, int] | None = None) -> dict:
    """Static VMEM/traffic model for ``row_sq_norms`` (used by DESIGN/EXPERIMENTS
    §Perf — interpret-mode wallclock is NOT a TPU proxy, structure is)."""
    bm, bk = block or pick_block(m, k)
    bm, bk = min(bm, m), min(bk, k)
    grid = (_ceil_div(m, bm), _ceil_div(k, bk))
    return {
        "block": (bm, bk),
        "grid": grid,
        "vmem_bytes": bm * bk * 4 + bm * 4,
        "hbm_read_bytes": m * k * 4,   # each element read exactly once
        "hbm_write_bytes": m * 4 * grid[1],
        "flops": 2 * m * k,            # square + add per element
        "arithmetic_intensity": (2 * m * k) / (m * k * 4),
    }
